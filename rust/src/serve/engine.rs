//! The prediction engine: evaluate stored paths at an arbitrary step
//! or regularization level, with request batching and an LRU
//! coefficient-snapshot cache.
//!
//! **Exactness contract** (covered by the property test in
//! `tests/serve.rs`): at a stored breakpoint — `Selector::Step(k)`, or
//! `Selector::Lambda(λ)` with λ exactly equal to a stored breakpoint —
//! the served prediction is **bit-identical** to evaluating the
//! fitter's returned coefficients directly: `dot(x, densify(coefs))`
//! with the same [`crate::linalg::dot`] kernel. Between breakpoints,
//! `Lambda` interpolates the coefficient vectors linearly in λ (exact
//! for LASSO-LARS paths, the standard approximation for plain
//! selection paths).
//!
//! **Batching**: [`PredictionEngine::predict_batch`] groups the rows of
//! a batch by (model, selector) and evaluates each group as one dense
//! GEMV through [`crate::linalg::DenseMatrix::gemv`] — the serving hot
//! path turns many scattered dot products into a single streaming pass
//! per model. The HTTP front end feeds this from concurrent
//! connections (see [`super::http`]).
//!
//! **Parallelism**: distinct groups are independent, so they fork onto
//! the [`crate::par`] pool, and each group's GEMV row-chunks onto the
//! same pool beneath that (nested joins run inline). `gemv` evaluates
//! every output row with the identical per-row [`dot`], so pool
//! execution cannot change a served bit — the exactness contract
//! survives parallelism by construction.

use super::store::{ModelRecord, ModelRegistry};
use crate::error::{anyhow, Result};
use crate::linalg::{dot, DenseMatrix};
use crate::select::{self, Criterion};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Where on the stored path to evaluate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Selector {
    /// Breakpoint index (0 = empty model).
    Step(usize),
    /// Regularization level; interpolated between breakpoints.
    Lambda(f64),
    /// Let an in-sample criterion ([`crate::select`]) choose the step
    /// on the stored path, per model. Needs the model's recorded
    /// training row count ([`crate::serve::ModelMeta::rows`]);
    /// [`Criterion::Cv`] is rejected at resolve time — run
    /// `POST /select` to compute (and cache) a CV choice first.
    Auto(Criterion),
}

impl Selector {
    fn cache_key(&self) -> SelKey {
        match *self {
            Selector::Step(k) => SelKey::Step(k as u64),
            Selector::Lambda(l) => SelKey::Lambda(l.to_bits()),
            Selector::Auto(c) => SelKey::Auto(c),
        }
    }
}

/// Hashable selector identity (λ by bit pattern).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum SelKey {
    Step(u64),
    Lambda(u64),
    Auto(Criterion),
}

/// One prediction query: model, path position, feature vector.
#[derive(Clone, Debug)]
pub struct Query {
    pub model: u64,
    pub selector: Selector,
    /// Dense feature vector, length = the model's `n`.
    pub x: Vec<f64>,
}

/// Engine counters exposed through `/stats`.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub queries: u64,
    pub batches: u64,
    pub batched_rows: u64,
    pub max_batch_rows: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub errors: u64,
}

#[derive(Default)]
struct Counters {
    queries: AtomicU64,
    batches: AtomicU64,
    batched_rows: AtomicU64,
    max_batch_rows: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    errors: AtomicU64,
}

/// LRU cache of densified coefficient vectors, keyed by
/// (model id, model version, selector). The version in the key makes a
/// re-registered model invalidate naturally.
struct CoefCache {
    map: HashMap<(u64, u32, SelKey), (u64, Arc<Vec<f64>>)>,
    capacity: usize,
    tick: u64,
}

impl CoefCache {
    fn new(capacity: usize) -> Self {
        CoefCache { map: HashMap::new(), capacity: capacity.max(1), tick: 0 }
    }

    fn get(&mut self, key: &(u64, u32, SelKey)) -> Option<Arc<Vec<f64>>> {
        self.tick += 1;
        let tick = self.tick;
        let entry = self.map.get_mut(key)?;
        entry.0 = tick;
        Some(entry.1.clone())
    }

    fn put(&mut self, key: (u64, u32, SelKey), v: Arc<Vec<f64>>) {
        self.tick += 1;
        if self.map.len() >= self.capacity && !self.map.contains_key(&key) {
            if let Some(victim) =
                self.map.iter().min_by_key(|(_, (t, _))| *t).map(|(k, _)| *k)
            {
                self.map.remove(&victim);
            }
        }
        let tick = self.tick;
        self.map.insert(key, (tick, v));
    }
}

/// Serves predictions from the registry's stored paths.
pub struct PredictionEngine {
    registry: Arc<ModelRegistry>,
    cache: Mutex<CoefCache>,
    counters: Counters,
}

impl PredictionEngine {
    pub fn new(registry: Arc<ModelRegistry>, cache_capacity: usize) -> Self {
        PredictionEngine {
            registry,
            cache: Mutex::new(CoefCache::new(cache_capacity)),
            counters: Counters::default(),
        }
    }

    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Dense length-`n` coefficient vector for `selector` on a model,
    /// through the LRU snapshot cache.
    pub fn coefs_for(&self, rec: &ModelRecord, selector: Selector) -> Result<Arc<Vec<f64>>> {
        let key = (rec.id, rec.version, selector.cache_key());
        {
            let mut cache =
                self.cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            if let Some(v) = cache.get(&key) {
                self.counters.cache_hits.fetch_add(1, Ordering::Relaxed);
                return Ok(v);
            }
        }
        self.counters.cache_misses.fetch_add(1, Ordering::Relaxed);
        let dense = Arc::new(resolve_coefs(rec, selector)?);
        self.cache.lock().unwrap_or_else(std::sync::PoisonError::into_inner).put(key, dense.clone());
        Ok(dense)
    }

    /// Evaluate a single query (unbatched path; same numerics as the
    /// batched one).
    pub fn predict(&self, q: &Query) -> Result<f64> {
        self.counters.queries.fetch_add(1, Ordering::Relaxed);
        match self.predict_inner(q) {
            Ok(v) => Ok(v),
            Err(e) => {
                self.counters.errors.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    fn predict_inner(&self, q: &Query) -> Result<f64> {
        let rec = self
            .registry
            .get(q.model)
            .ok_or_else(|| anyhow!("unknown model {}", q.model))?;
        if q.x.len() != rec.snapshot.n {
            return Err(anyhow!(
                "query dimension {} != model dimension {}",
                q.x.len(),
                rec.snapshot.n
            ));
        }
        let coefs = self.coefs_for(&rec, q.selector)?;
        Ok(dot(&q.x, &coefs))
    }

    /// Evaluate a batch: rows are grouped by (model, selector), groups
    /// fork onto the [`crate::par`] pool, and each group runs as one
    /// dense GEMV. Per-query failures (unknown model, dimension
    /// mismatch, bad selector) fail only that query.
    pub fn predict_batch(&self, queries: &[Query]) -> Vec<Result<f64>> {
        self.counters.queries.fetch_add(queries.len() as u64, Ordering::Relaxed);
        self.counters.batches.fetch_add(1, Ordering::Relaxed);
        self.counters.batched_rows.fetch_add(queries.len() as u64, Ordering::Relaxed);
        self.counters.max_batch_rows.fetch_max(queries.len() as u64, Ordering::Relaxed);
        batch_rows_histogram().observe(queries.len() as f64);

        let mut groups: HashMap<(u64, SelKey), Vec<usize>> = HashMap::new();
        for (i, q) in queries.iter().enumerate() {
            groups.entry((q.model, q.selector.cache_key())).or_default().push(i);
        }

        // Cross-request parallelism: every (model, selector) group is
        // independent, so the groups themselves are fork-join tasks.
        // Each returns (query index, result) pairs; scattering them
        // back by index makes the output order — and every served bit —
        // independent of both HashMap iteration and task scheduling.
        let tasks: Vec<_> = groups
            .into_iter()
            .map(|((model, _), idxs)| move || self.eval_group(queries, model, idxs))
            .collect();
        let mut out: Vec<Option<Result<f64>>> = queries.iter().map(|_| None).collect();
        for (i, res) in crate::par::run_tasks(tasks).into_iter().flatten() {
            out[i] = Some(res);
        }
        out.into_iter()
            .map(|o| {
                o.unwrap_or_else(|| {
                    Err(crate::error::Error::internal(
                        "batch evaluation missed a query (scatter bug)",
                    ))
                })
            })
            .collect()
    }

    /// Evaluate one (model, selector) group of a batch; `idxs` are the
    /// group's indices into `queries`.
    fn eval_group(
        &self,
        queries: &[Query],
        model: u64,
        idxs: Vec<usize>,
    ) -> Vec<(usize, Result<f64>)> {
        let selector = queries[idxs[0]].selector;
        let rec = match self.registry.get(model) {
            Some(r) => r,
            None => {
                self.counters.errors.fetch_add(idxs.len() as u64, Ordering::Relaxed);
                return idxs
                    .into_iter()
                    .map(|i| (i, Err(anyhow!("unknown model {model}"))))
                    .collect();
            }
        };
        let coefs = match self.coefs_for(&rec, selector) {
            Ok(c) => c,
            Err(e) => {
                self.counters.errors.fetch_add(idxs.len() as u64, Ordering::Relaxed);
                return idxs.into_iter().map(|i| (i, Err(e.clone()))).collect();
            }
        };
        let mut out: Vec<(usize, Result<f64>)> = Vec::with_capacity(idxs.len());
        let mut rows: Vec<&[f64]> = Vec::with_capacity(idxs.len());
        let mut row_idx: Vec<usize> = Vec::with_capacity(idxs.len());
        for &i in &idxs {
            if queries[i].x.len() == rec.snapshot.n {
                rows.push(&queries[i].x);
                row_idx.push(i);
            } else {
                out.push((
                    i,
                    Err(anyhow!(
                        "query dimension {} != model dimension {}",
                        queries[i].x.len(),
                        rec.snapshot.n
                    )),
                ));
                self.counters.errors.fetch_add(1, Ordering::Relaxed);
            }
        }
        match row_idx.len() {
            0 => {}
            1 => out.push((row_idx[0], Ok(dot(rows[0], &coefs)))),
            _ => {
                // The batched hot path: one GEMV for the whole group.
                // `gemv` computes dot(row_i, coefs) per row — the same
                // kernel and operand order as the single-query path,
                // so batching never changes a result bit.
                let mat = DenseMatrix::from_rows(&rows);
                let mut ys = vec![0.0; rows.len()];
                mat.gemv(&coefs, &mut ys);
                for (&i, y) in row_idx.iter().zip(ys) {
                    out.push((i, Ok(y)));
                }
            }
        }
        out
    }

    /// Counter snapshot for `/stats`.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            queries: self.counters.queries.load(Ordering::Relaxed),
            batches: self.counters.batches.load(Ordering::Relaxed),
            batched_rows: self.counters.batched_rows.load(Ordering::Relaxed),
            max_batch_rows: self.counters.max_batch_rows.load(Ordering::Relaxed),
            cache_hits: self.counters.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.counters.cache_misses.load(Ordering::Relaxed),
            errors: self.counters.errors.load(Ordering::Relaxed),
        }
    }
}

/// Batch-size histogram in the global metrics registry (power-of-two
/// row-count buckets), registered once and cloned thereafter.
fn batch_rows_histogram() -> crate::obs::Histogram {
    static H: std::sync::OnceLock<crate::obs::Histogram> = std::sync::OnceLock::new();
    H.get_or_init(|| {
        let bounds: Vec<f64> = (0..=10).map(|i| (1u64 << i) as f64).collect();
        crate::obs::global().histogram(
            "calars_predict_batch_rows",
            "",
            "Rows per drained prediction batch.",
            &bounds,
        )
    })
    .clone()
}

/// Resolve a selector to a dense coefficient vector on one record.
fn resolve_coefs(rec: &ModelRecord, selector: Selector) -> Result<Vec<f64>> {
    let snap = &rec.snapshot;
    match selector {
        Selector::Step(k) => snap.dense_coefs(k).ok_or_else(|| {
            anyhow!("model {} stores steps 0..{}, step {k} out of range", rec.id, snap.len())
        }),
        Selector::Lambda(l) => {
            if !l.is_finite() || l < 0.0 {
                return Err(anyhow!("lambda must be finite and ≥ 0, got {l}"));
            }
            if snap.steps.is_empty() {
                return Err(anyhow!("model {} stores an empty path", rec.id));
            }
            // The step indices below all come from snap itself, so a
            // miss is an internal inconsistency, not a caller error.
            let coefs_at = |k: usize| {
                snap.dense_coefs(k).ok_or_else(|| {
                    crate::error::Error::internal(format!(
                        "model {}: stored step {k} has no coefficients",
                        rec.id
                    ))
                })
            };
            // Exact breakpoint hit → the stored vector, bit-identical.
            if let Some(k) = snap.steps.iter().position(|s| s.lambda == l) {
                return coefs_at(k);
            }
            // Outside the stored range → clamp to the nearest end.
            if l >= snap.steps[0].lambda {
                return coefs_at(0);
            }
            let last = snap.steps.len() - 1;
            if l <= snap.steps[last].lambda {
                return coefs_at(last);
            }
            // Bracket and interpolate linearly in λ.
            for k in 0..last {
                let (hi, lo) = (snap.steps[k].lambda, snap.steps[k + 1].lambda);
                if l < hi && l > lo {
                    let t = (hi - l) / (hi - lo);
                    let a = coefs_at(k)?;
                    let b = coefs_at(k + 1)?;
                    return Ok(a
                        .iter()
                        .zip(&b)
                        .map(|(ai, bi)| ai + t * (bi - ai))
                        .collect());
                }
            }
            Err(anyhow!("lambda {l} not bracketed by model {}'s path", rec.id))
        }
        Selector::Auto(criterion) => {
            if criterion == Criterion::Cv {
                return Err(anyhow!(
                    "selector 'auto cv' cannot resolve at predict time (it needs fold \
                     refits); POST /select with criterion cv, then predict the \
                     returned step"
                ));
            }
            let sel = select::rank_steps(snap, rec.meta.rows, criterion)
                .map_err(|e| e.context(format!("auto-selection on model {}", rec.id)))?;
            snap.dense_coefs(sel.best_step).ok_or_else(|| {
                crate::error::Error::internal(format!(
                    "model {}: auto-selected step {} has no stored coefficients",
                    rec.id, sel.best_step
                ))
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lars::path::{PathSnapshot, PathStep};
    use crate::serve::store::ModelMeta;

    fn registry_with_path() -> (Arc<ModelRegistry>, u64) {
        // n = 3; step 1 activates col 2, step 2 adds col 0.
        let steps = vec![
            PathStep { lambda: 4.0, support: vec![], coefs: vec![], residual_norm: 5.0 },
            PathStep {
                lambda: 2.0,
                support: vec![2],
                coefs: vec![1.5],
                residual_norm: 3.0,
            },
            PathStep {
                lambda: 1.0,
                support: vec![2, 0],
                coefs: vec![2.0, -0.5],
                residual_norm: 1.0,
            },
        ];
        let reg = Arc::new(ModelRegistry::new(4));
        let id = reg.insert(ModelMeta::named("toy"), PathSnapshot { n: 3, steps });
        (reg, id)
    }

    #[test]
    fn step_selector_is_exact() {
        let (reg, id) = registry_with_path();
        let eng = PredictionEngine::new(reg, 8);
        let x = vec![10.0, 100.0, 1.0];
        let q = |s| Query { model: id, selector: s, x: x.clone() };
        assert_eq!(eng.predict(&q(Selector::Step(0))).unwrap(), 0.0);
        assert_eq!(eng.predict(&q(Selector::Step(1))).unwrap(), dot(&x, &[0.0, 0.0, 1.5]));
        assert_eq!(eng.predict(&q(Selector::Step(2))).unwrap(), dot(&x, &[-0.5, 0.0, 2.0]));
        assert!(eng.predict(&q(Selector::Step(3))).is_err());
    }

    #[test]
    fn lambda_exact_hit_uses_stored_vector() {
        let (reg, id) = registry_with_path();
        let eng = PredictionEngine::new(reg, 8);
        let x = vec![1.0, 1.0, 1.0];
        let at_step = eng
            .predict(&Query { model: id, selector: Selector::Step(1), x: x.clone() })
            .unwrap();
        let at_lambda = eng
            .predict(&Query { model: id, selector: Selector::Lambda(2.0), x })
            .unwrap();
        assert_eq!(at_step.to_bits(), at_lambda.to_bits(), "breakpoint hit must be bit-identical");
    }

    #[test]
    fn lambda_interpolates_and_clamps() {
        let (reg, id) = registry_with_path();
        let eng = PredictionEngine::new(reg, 8);
        let x = vec![0.0, 0.0, 1.0]; // reads coefficient of col 2
        let p = |l| {
            eng.predict(&Query { model: id, selector: Selector::Lambda(l), x: x.clone() })
                .unwrap()
        };
        // Midway between λ=2 (coef 1.5) and λ=1 (coef 2.0).
        assert!((p(1.5) - 1.75).abs() < 1e-12);
        // Above λmax → empty model; below λmin → final model.
        assert_eq!(p(10.0), 0.0);
        assert_eq!(p(0.1), 2.0);
        assert!(eng
            .predict(&Query { model: id, selector: Selector::Lambda(f64::NAN), x: x.clone() })
            .is_err());
    }

    #[test]
    fn batch_matches_single_bitwise_and_counts_cache() {
        let (reg, id) = registry_with_path();
        let eng = PredictionEngine::new(reg, 8);
        let queries: Vec<Query> = (0..6)
            .map(|i| Query {
                model: id,
                selector: if i % 2 == 0 { Selector::Step(1) } else { Selector::Step(2) },
                x: vec![i as f64, 1.0 - i as f64, 0.25 * i as f64],
            })
            .collect();
        let batch = eng.predict_batch(&queries);
        for (q, r) in queries.iter().zip(&batch) {
            let single = eng.predict(q).unwrap();
            assert_eq!(
                r.as_ref().unwrap().to_bits(),
                single.to_bits(),
                "batched result must equal unbatched bit for bit"
            );
        }
        let s = eng.stats();
        assert_eq!(s.batches, 1);
        assert_eq!(s.batched_rows, 6);
        assert_eq!(s.cache_misses, 2, "two distinct (model, step) groups");
        assert!(s.cache_hits >= 6, "repeat predicts hit the snapshot cache");
    }

    #[test]
    fn batch_isolates_per_query_failures() {
        let (reg, id) = registry_with_path();
        let eng = PredictionEngine::new(reg, 8);
        let queries = vec![
            Query { model: id, selector: Selector::Step(1), x: vec![1.0, 2.0, 3.0] },
            Query { model: 999, selector: Selector::Step(0), x: vec![1.0, 2.0, 3.0] },
            Query { model: id, selector: Selector::Step(1), x: vec![1.0] }, // bad dim
        ];
        let r = eng.predict_batch(&queries);
        assert!(r[0].is_ok());
        assert!(r[1].is_err());
        assert!(r[2].is_err());
        assert_eq!(eng.stats().errors, 2);
    }

    #[test]
    fn auto_selector_resolves_via_in_sample_criterion() {
        let (reg, id) = registry_with_path();
        {
            // Ad-hoc insert (training row count unknown): typed error,
            // not a panic.
            let eng = PredictionEngine::new(reg.clone(), 8);
            let q = Query {
                model: id,
                selector: Selector::Auto(Criterion::Aic),
                x: vec![1.0, 1.0, 1.0],
            };
            assert!(eng.predict(&q).is_err());
        }
        let mut meta = ModelMeta::named("auto");
        meta.rows = 50;
        let snap = reg.get(id).unwrap().snapshot.clone();
        let id2 = reg.insert(meta, snap);
        let eng = PredictionEngine::new(reg, 8);
        let x = vec![10.0, 100.0, 1.0];
        // Residuals fall 5 → 3 → 1 on m = 50: AIC favors the final
        // step, so Auto(Aic) must serve exactly Step(2)'s bits.
        let auto = eng
            .predict(&Query { model: id2, selector: Selector::Auto(Criterion::Aic), x: x.clone() })
            .unwrap();
        let at2 = eng
            .predict(&Query { model: id2, selector: Selector::Step(2), x: x.clone() })
            .unwrap();
        assert_eq!(auto.to_bits(), at2.to_bits());
        // CV cannot resolve lazily at predict time.
        let err = eng
            .predict(&Query { model: id2, selector: Selector::Auto(Criterion::Cv), x })
            .unwrap_err();
        assert!(format!("{err:#}").contains("/select"), "{err:#}");
    }

    #[test]
    fn cache_evicts_least_recent() {
        let mut cache = CoefCache::new(2);
        let k = |i: u64| (i, 1u32, SelKey::Step(0));
        cache.put(k(1), Arc::new(vec![1.0]));
        cache.put(k(2), Arc::new(vec![2.0]));
        cache.get(&k(1));
        cache.put(k(3), Arc::new(vec![3.0]));
        assert!(cache.get(&k(2)).is_none(), "LRU entry evicted");
        assert!(cache.get(&k(1)).is_some());
        assert!(cache.get(&k(3)).is_some());
    }
}
