//! The model registry: versioned storage for fitted path snapshots.
//!
//! A fitted LARS/bLARS/T-bLARS run is snapshotted once
//! ([`PathSnapshot`]) and then served forever after; the registry is
//! the in-memory home of those snapshots plus a compact on-disk format
//! (`*.calp`, magic `CALP`, format-versioned) so a serving process can
//! restart without refitting.
//!
//! Semantics the serving layer relies on (covered by `tests/serve.rs`):
//!
//! * **Insert** assigns a fresh monotonically increasing id; a model
//!   whose [`ModelMeta::family_key`] matches an existing record gets
//!   `version = max(existing) + 1` (the old version stays addressable
//!   until evicted).
//! * **Evict**: the registry holds at most `capacity` models; inserting
//!   past that evicts the least-recently-*used* model (a `get` counts
//!   as use, a `list` does not).
//! * **Warm-start reuse**: a fit request whose family already has a
//!   stored path covering at least the requested `t` steps is served
//!   from the existing snapshot — the path *is* the sequence of models,
//!   so a shorter prefix is free (the paper's "sequence of linear
//!   models" consumed as such).

use crate::error::{bail, Context, Result};
use crate::lars::path::{PathSnapshot, PathStep};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{SystemTime, UNIX_EPOCH};

/// Descriptive metadata attached to a stored model.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelMeta {
    /// Human-readable name (generated from the family if empty).
    pub name: String,
    /// Fitting algorithm ("lars" | "blars" | "tblars" | "lasso").
    pub algo: String,
    /// Dataset the model was fitted on (registry family identity).
    pub dataset: String,
    /// Requested path length (selected columns).
    pub t: usize,
    /// Block size used by the fit.
    pub b: usize,
    /// Simulated ranks used by the fit (T-bLARS selections depend on
    /// the induced column partition, so this is part of the identity).
    pub p: usize,
    /// Fit seed.
    pub seed: u64,
    /// Why the fitted path ended
    /// ([`crate::lars::StopReason::word`]; "" for ad-hoc inserts) —
    /// surfaced through `/models` so operators can tell a completed
    /// path from a saturated or rank-deficient one.
    pub stop: String,
    /// Canonical [`crate::fit::FitSpec::encode`] string of the fit
    /// ("" for ad-hoc inserts).
    pub spec: String,
    /// Training row count the path was fitted on (0 for ad-hoc
    /// inserts/legacy files). The in-sample selection criteria
    /// ([`crate::select`]) need it: Cp/AIC/BIC all charge degrees of
    /// freedom against `m`.
    pub rows: usize,
    /// Model-selection tokens (`"cp=4 aic=5 cv5.0=3"`; see
    /// [`crate::select::find_selection`]) — which path step each
    /// criterion chose, precomputed at fit time for the in-sample
    /// criteria and updated by `POST /select` for CV. Surfaced through
    /// `/models`.
    pub selection: String,
}

impl ModelMeta {
    /// Minimal metadata carrying only a display name (tests, ad-hoc
    /// inserts).
    pub fn named(name: &str) -> Self {
        ModelMeta {
            name: name.to_string(),
            algo: "lars".to_string(),
            dataset: String::new(),
            t: 0,
            b: 1,
            p: 1,
            seed: 0,
            stop: String::new(),
            spec: String::new(),
            rows: 0,
            selection: String::new(),
        }
    }

    /// Identity used for versioning and warm-start reuse: two fits of
    /// the same dataset with the same algorithm, block size, rank
    /// count, seed, **and non-`t` spec knobs** belong to the same
    /// family (their paths are prefixes of each other — `p` matters
    /// because the T-bLARS tournament selects against the `p`-way
    /// column partition, and the canonical spec string matters because
    /// knobs like `tol` or `partition_seed` change which columns a fit
    /// selects; only `t`, the path length, is stripped). The empty
    /// dataset never forms a family, and neither do LASSO fits: their
    /// paths are truncated by the λ floor rather than by `t`, so a
    /// stored path covering `t` columns is not necessarily a prefix of
    /// a deeper fit.
    pub fn family_key(&self) -> Option<String> {
        if self.dataset.is_empty() || self.algo == "lasso" {
            return None;
        }
        // The encoded FitSpec minus the tokens that cannot change the
        // fitted path: `t=` (the path length — a longer path of the
        // same family covers a shorter one), `ranks=`/`parts=` (raw
        // request values; the normalized count the fit actually uses
        // is the `p` field below, so keeping them would fragment
        // families that fit identically, e.g. p=5 vs p=8), and `mode=`
        // (threaded and sequential execution are bit-identical by the
        // crate's determinism contract).
        let spec_knobs: Vec<&str> = self
            .spec
            .split_whitespace()
            .filter(|tok| {
                !tok.starts_with("t=")
                    && !tok.starts_with("ranks=")
                    && !tok.starts_with("parts=")
                    && !tok.starts_with("mode=")
            })
            .collect();
        Some(format!(
            "{}|{}|{}|{}|{}|{}",
            self.dataset,
            self.algo,
            self.b,
            self.p,
            self.seed,
            spec_knobs.join(" ")
        ))
    }

    /// Display name, falling back to a generated one.
    pub fn display_name(&self) -> String {
        if self.name.is_empty() {
            format!("{}-{}-t{}", self.dataset, self.algo, self.t)
        } else {
            self.name.clone()
        }
    }
}

/// One stored model: metadata + the fitted path snapshot.
#[derive(Clone, Debug)]
pub struct ModelRecord {
    pub id: u64,
    /// Bumped when a fit replaces an earlier member of the same family.
    pub version: u32,
    pub meta: ModelMeta,
    pub snapshot: PathSnapshot,
    /// Unix timestamp (seconds) of registration.
    pub created_unix: u64,
}

/// Registry counters exposed through `/stats`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RegistryStats {
    pub models: usize,
    pub inserted: u64,
    pub evicted: u64,
    pub warm_reused: u64,
    pub approx_bytes: usize,
}

struct Inner {
    models: HashMap<u64, Arc<ModelRecord>>,
    /// LRU order: front = least recently used.
    lru: Vec<u64>,
    next_id: u64,
    inserted: u64,
    evicted: u64,
    warm_reused: u64,
}

/// Thread-safe, capacity-bounded model store.
///
/// With a persist directory attached ([`Self::with_persist_dir`]),
/// every insert writes through to disk immediately and evictions/
/// removals delete their file — a SIGKILL after a fit completes loses
/// nothing, without relying on a graceful-shutdown sweep.
pub struct ModelRegistry {
    capacity: usize,
    inner: Mutex<Inner>,
    persist_dir: Option<PathBuf>,
}

fn now_unix() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs()).unwrap_or(0)
}

impl ModelRegistry {
    /// Registry holding at most `capacity` models (≥ 1).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "registry capacity must be ≥ 1");
        ModelRegistry {
            capacity,
            inner: Mutex::new(Inner {
                models: HashMap::new(),
                lru: Vec::new(),
                next_id: 1,
                inserted: 0,
                evicted: 0,
                warm_reused: 0,
            }),
            persist_dir: None,
        }
    }

    /// Registry backed by `dir`: existing `*.calp` files are loaded,
    /// and from then on every insert writes through to disk while
    /// evictions and removals delete their file. Files that did not
    /// survive loading (over-capacity eviction, manual orphans) are
    /// swept from disk so disk and memory agree.
    pub fn with_persist_dir(dir: &Path, capacity: usize) -> Result<Self> {
        let mut reg = if dir.is_dir() {
            Self::load_dir(dir, capacity)?
        } else {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("create registry dir {}", dir.display()))?;
            Self::new(capacity)
        };
        reg.persist_dir = Some(dir.to_path_buf());
        let live = {
            let g = reg.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            g.models.keys().copied().collect::<std::collections::HashSet<u64>>()
        };
        for entry in std::fs::read_dir(dir)
            .with_context(|| format!("read registry dir {}", dir.display()))?
        {
            let Ok(entry) = entry else { continue };
            let path = entry.path();
            if path.extension().map_or(false, |x| x == "calp") {
                let id = path
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .and_then(|s| s.parse::<u64>().ok());
                if id.map_or(true, |id| !live.contains(&id)) {
                    let _ = std::fs::remove_file(&path);
                }
            }
        }
        Ok(reg)
    }

    fn record_path(dir: &Path, id: u64) -> PathBuf {
        dir.join(format!("{id:08}.calp"))
    }

    /// Register a snapshot; returns the new model id. Evicts the
    /// least-recently-used model when over capacity. With a persist
    /// directory, the record is written to disk before this returns
    /// (write-through; IO failures are logged, not fatal — the
    /// in-memory registry stays authoritative).
    pub fn insert(&self, meta: ModelMeta, snapshot: PathSnapshot) -> u64 {
        self.insert_many(vec![(meta, snapshot)])[0]
    }

    /// Register a whole batch of snapshots under **one** lock
    /// acquisition — the bulk `/fit` path's registry transaction. Ids
    /// are assigned in input order and the batch becomes visible
    /// atomically: a concurrent `list`/`get` sees either none of the
    /// batch or all of it, and no other insert can interleave its ids
    /// into the batch's range. Versioning, LRU eviction, and
    /// write-through persistence behave exactly like [`Self::insert`]
    /// (family versions resolve incrementally, so two same-family
    /// members of one batch get consecutive versions).
    pub fn insert_many(&self, entries: Vec<(ModelMeta, PathSnapshot)>) -> Vec<u64> {
        let mut ids = Vec::with_capacity(entries.len());
        if entries.is_empty() {
            return ids;
        }
        let mut g = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let mut recs: Vec<Arc<ModelRecord>> = Vec::with_capacity(entries.len());
        for (meta, snapshot) in entries {
            let version = match meta.family_key() {
                Some(key) => {
                    g.models
                        .values()
                        .filter(|r| r.meta.family_key().as_deref() == Some(key.as_str()))
                        .map(|r| r.version)
                        .max()
                        .unwrap_or(0)
                        + 1
                }
                None => 1,
            };
            let id = g.next_id;
            g.next_id += 1;
            let rec =
                Arc::new(ModelRecord { id, version, meta, snapshot, created_unix: now_unix() });
            g.models.insert(id, rec.clone());
            g.lru.push(id);
            g.inserted += 1;
            recs.push(rec);
            ids.push(id);
        }
        let mut victims = Vec::new();
        while g.models.len() > self.capacity {
            let victim = g.lru.remove(0);
            g.models.remove(&victim);
            g.evicted += 1;
            victims.push(victim);
        }
        // File IO deliberately happens under the lock: it serializes
        // this record's write against a concurrent insert's eviction
        // of it (otherwise delete-before-write could leave an orphan
        // file). Inserts are fit-completion rare; the brief stall of
        // concurrent get()s is an acceptable price for consistency.
        if let Some(dir) = &self.persist_dir {
            for rec in &recs {
                // A batch larger than the capacity evicts its own
                // oldest members before this point; never write them.
                if victims.contains(&rec.id) {
                    continue;
                }
                let mut buf = Vec::new();
                let write = write_record(&mut buf, rec).and_then(|_| {
                    std::fs::write(Self::record_path(dir, rec.id), &buf).map_err(Into::into)
                });
                if let Err(e) = write {
                    eprintln!("registry: persisting model {} failed: {e:#}", rec.id);
                }
            }
            for victim in &victims {
                let _ = std::fs::remove_file(Self::record_path(dir, *victim));
            }
        }
        ids
    }

    /// Fetch a model and mark it most-recently-used.
    pub fn get(&self, id: u64) -> Option<Arc<ModelRecord>> {
        let mut g = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let rec = g.models.get(&id)?.clone();
        if let Some(pos) = g.lru.iter().position(|&x| x == id) {
            g.lru.remove(pos);
            g.lru.push(id);
        }
        Some(rec)
    }

    /// All models, ascending id (does not touch LRU order).
    pub fn list(&self) -> Vec<Arc<ModelRecord>> {
        let g = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let mut out: Vec<Arc<ModelRecord>> = g.models.values().cloned().collect();
        out.sort_by_key(|r| r.id);
        out
    }

    /// Remove a model; true if it existed.
    pub fn remove(&self, id: u64) -> bool {
        let mut g = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if let Some(pos) = g.lru.iter().position(|&x| x == id) {
            g.lru.remove(pos);
        }
        let existed = g.models.remove(&id).is_some();
        if existed {
            // Under the lock for the same write/delete ordering reason
            // as insert().
            if let Some(dir) = &self.persist_dir {
                let _ = std::fs::remove_file(Self::record_path(dir, id));
            }
        }
        existed
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner).models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Upsert one selection token (`key=step`; see
    /// [`crate::select::upsert_selection`]) in a model's metadata,
    /// **atomically under the registry lock** — concurrent
    /// `POST /select`s for different criteria must not lose each
    /// other's tokens to a read-modify-write race. Returns false for
    /// an unknown id. With a persist directory the updated record
    /// writes through like an insert.
    pub fn record_selection(&self, id: u64, key: &str, step: usize) -> bool {
        let mut g = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let Some(rec) = g.models.get(&id) else { return false };
        // Repeated selections of an unchanged criterion are the common
        // case (every in-sample /select lands here): skip the record
        // rewrite — and above all the disk write — when the token is
        // already present with the same value.
        if crate::select::find_selection(&rec.meta.selection, key) == Some(step) {
            return true;
        }
        let mut updated = ModelRecord::clone(rec);
        updated.meta.selection =
            crate::select::upsert_selection(&updated.meta.selection, key, step);
        let updated = Arc::new(updated);
        g.models.insert(id, updated.clone());
        // The file write stays under the lock for the same
        // write/delete ordering reason as insert(): a concurrent
        // insert's eviction of this id must not race our write into
        // resurrecting a deleted record file. The no-op skip above
        // keeps the common path free of it.
        if let Some(dir) = &self.persist_dir {
            let mut buf = Vec::new();
            let write = write_record(&mut buf, &updated)
                .and_then(|_| std::fs::write(Self::record_path(dir, id), &buf).map_err(Into::into));
            if let Err(e) = write {
                eprintln!("registry: persisting selection for model {id} failed: {e:#}");
            }
        }
        true
    }

    /// Warm-start lookup: a model of the same family whose stored path
    /// already covers `t` selected columns. Counts as a use (LRU) and
    /// as a warm reuse (stats).
    ///
    /// When no stored path covers `t` (a *deeper* refit of the family),
    /// the fit reruns — but its selection prefix repeats the covered
    /// path's, so the per-dataset
    /// [`GramCache`](crate::serve::GramCache) the queue binds around
    /// fits serves those iterations' Gram panels from cache; the two
    /// layers together make family refits cheap at every depth.
    pub fn find_warm(&self, meta: &ModelMeta, t: usize) -> Option<Arc<ModelRecord>> {
        let key = meta.family_key()?;
        let mut g = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let rec = g
            .models
            .values()
            .filter(|r| {
                r.meta.family_key().as_deref() == Some(key.as_str())
                    && r.snapshot.max_support() >= t
                    // Legacy records (CALP format ≤ 2) carry rows = 0,
                    // which blocks the in-sample selection criteria;
                    // reusing one would make the "refit to record it"
                    // remedy a no-op forever. Refit instead.
                    && r.meta.rows > 0
            })
            .max_by_key(|r| r.version)
            .cloned()?;
        let id = rec.id;
        if let Some(pos) = g.lru.iter().position(|&x| x == id) {
            g.lru.remove(pos);
            g.lru.push(id);
        }
        g.warm_reused += 1;
        Some(rec)
    }

    /// Counter snapshot for `/stats`.
    pub fn stats(&self) -> RegistryStats {
        let g = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        RegistryStats {
            models: g.models.len(),
            inserted: g.inserted,
            evicted: g.evicted,
            warm_reused: g.warm_reused,
            approx_bytes: g.models.values().map(|r| r.snapshot.approx_bytes()).sum(),
        }
    }

    /// Persist every model as `<id>.calp` under `dir`; returns the
    /// number written.
    pub fn save_dir(&self, dir: &Path) -> Result<usize> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create registry dir {}", dir.display()))?;
        let models = self.list();
        for rec in &models {
            let path = Self::record_path(dir, rec.id);
            let mut buf = Vec::new();
            write_record(&mut buf, rec)?;
            std::fs::write(&path, &buf)
                .with_context(|| format!("write {}", path.display()))?;
        }
        Ok(models.len())
    }

    /// Rebuild a registry from a directory written by [`Self::save_dir`]
    /// (ids and versions are preserved; LRU order is id order).
    pub fn load_dir(dir: &Path, capacity: usize) -> Result<Self> {
        let reg = ModelRegistry::new(capacity);
        let mut paths: Vec<_> = std::fs::read_dir(dir)
            .with_context(|| format!("read registry dir {}", dir.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().map_or(false, |x| x == "calp"))
            .collect();
        paths.sort();
        let mut g = reg.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        for path in paths {
            let bytes = std::fs::read(&path)
                .with_context(|| format!("read {}", path.display()))?;
            let rec = read_record(&mut bytes.as_slice())
                .with_context(|| format!("parse {}", path.display()))?;
            g.next_id = g.next_id.max(rec.id + 1);
            g.lru.push(rec.id);
            g.models.insert(rec.id, Arc::new(rec));
            g.inserted += 1;
            while g.models.len() > capacity {
                let victim = g.lru.remove(0);
                g.models.remove(&victim);
                g.evicted += 1;
            }
        }
        drop(g);
        Ok(reg)
    }
}

// ── on-disk format ──────────────────────────────────────────────────
//
// Little-endian, fixed layout, format-versioned:
//
//   b"CALP" | u32 format | u64 id | u32 version | u64 created_unix
//   | str name | str algo | str dataset | u64 t | u64 b | u64 p
//   | u64 seed | str stop | str spec          (stop/spec: format ≥ 2)
//   | u64 rows | str selection               (rows/selection: format ≥ 3)
//   | u64 n | u64 nsteps
//   | nsteps × ( f64 lambda | f64 residual_norm | u64 k
//                | k × u64 support | k × f64 coefs )
//
// where `str` is u32 length + UTF-8 bytes. f64s round-trip bit-exactly
// (to_le_bytes/from_le_bytes), which the serving exactness contract
// depends on. Format 1 files (pre-estimator-API) still load with empty
// stop/spec metadata; format ≤ 2 files load with rows = 0 and no
// selection tokens (the in-sample criteria then ask for a refit).

const MAGIC: &[u8; 4] = b"CALP";
const FORMAT: u32 = 3;
const MIN_FORMAT: u32 = 1;
/// Sanity caps for corrupt files (not real limits).
const MAX_STR: u32 = 1 << 16;
const MAX_STEPS: u64 = 1 << 24;
const MAX_SUPPORT: u64 = 1 << 24;
const MAX_DIM: u64 = 1 << 32;

fn w_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_f64(w: &mut impl Write, v: f64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn w_str(w: &mut impl Write, s: &str) -> Result<()> {
    let bytes = s.as_bytes();
    if bytes.len() as u64 > MAX_STR as u64 {
        bail!("string too long for registry format ({} bytes)", bytes.len());
    }
    w_u32(w, bytes.len() as u32)?;
    w.write_all(bytes)?;
    Ok(())
}

fn r_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn r_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn r_f64(r: &mut impl Read) -> Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

fn r_str(r: &mut impl Read) -> Result<String> {
    let len = r_u32(r)?;
    if len > MAX_STR {
        bail!("string length {len} exceeds cap");
    }
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)?;
    String::from_utf8(buf).context("invalid UTF-8 in registry file")
}

/// Serialize one record (see the format comment above).
pub fn write_record(w: &mut impl Write, rec: &ModelRecord) -> Result<()> {
    w.write_all(MAGIC)?;
    w_u32(w, FORMAT)?;
    w_u64(w, rec.id)?;
    w_u32(w, rec.version)?;
    w_u64(w, rec.created_unix)?;
    w_str(w, &rec.meta.name)?;
    w_str(w, &rec.meta.algo)?;
    w_str(w, &rec.meta.dataset)?;
    w_u64(w, rec.meta.t as u64)?;
    w_u64(w, rec.meta.b as u64)?;
    w_u64(w, rec.meta.p as u64)?;
    w_u64(w, rec.meta.seed)?;
    w_str(w, &rec.meta.stop)?;
    w_str(w, &rec.meta.spec)?;
    w_u64(w, rec.meta.rows as u64)?;
    w_str(w, &rec.meta.selection)?;
    w_u64(w, rec.snapshot.n as u64)?;
    w_u64(w, rec.snapshot.steps.len() as u64)?;
    for step in &rec.snapshot.steps {
        w_f64(w, step.lambda)?;
        w_f64(w, step.residual_norm)?;
        w_u64(w, step.support.len() as u64)?;
        for &j in &step.support {
            w_u64(w, j as u64)?;
        }
        for &v in &step.coefs {
            w_f64(w, v)?;
        }
    }
    Ok(())
}

/// Deserialize one record written by [`write_record`].
pub fn read_record(r: &mut impl Read) -> Result<ModelRecord> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a calars model file (bad magic)");
    }
    let format = r_u32(r)?;
    if !(MIN_FORMAT..=FORMAT).contains(&format) {
        bail!("unsupported registry format {format} (this build reads {MIN_FORMAT}..={FORMAT})");
    }
    let id = r_u64(r)?;
    let version = r_u32(r)?;
    let created_unix = r_u64(r)?;
    let name = r_str(r)?;
    let algo = r_str(r)?;
    let dataset = r_str(r)?;
    let t = r_u64(r)? as usize;
    let b = r_u64(r)? as usize;
    let p = r_u64(r)? as usize;
    let seed = r_u64(r)?;
    let (stop, spec) = if format >= 2 {
        (r_str(r)?, r_str(r)?)
    } else {
        (String::new(), String::new())
    };
    let (rows, selection) = if format >= 3 {
        (r_u64(r)? as usize, r_str(r)?)
    } else {
        (0, String::new())
    };
    let n64 = r_u64(r)?;
    if n64 > MAX_DIM {
        bail!("feature dimension {n64} exceeds cap");
    }
    let n = n64 as usize;
    let nsteps = r_u64(r)?;
    if nsteps > MAX_STEPS {
        bail!("step count {nsteps} exceeds cap");
    }
    let mut steps = Vec::with_capacity(nsteps as usize);
    for _ in 0..nsteps {
        let lambda = r_f64(r)?;
        let residual_norm = r_f64(r)?;
        let k = r_u64(r)?;
        if k > MAX_SUPPORT || k > n64 {
            bail!("support size {k} exceeds cap (n = {n64})");
        }
        let mut support = Vec::with_capacity(k as usize);
        for _ in 0..k {
            let j = r_u64(r)?;
            // Validate here so a corrupt file fails at load time instead
            // of panicking densify() inside the serving batcher later.
            if j >= n64 {
                bail!("support index {j} out of range for dimension {n64}");
            }
            support.push(j as usize);
        }
        let mut coefs = Vec::with_capacity(k as usize);
        for _ in 0..k {
            coefs.push(r_f64(r)?);
        }
        steps.push(PathStep { lambda, support, coefs, residual_norm });
    }
    Ok(ModelRecord {
        id,
        version,
        meta: ModelMeta { name, algo, dataset, t, b, p, seed, stop, spec, rows, selection },
        snapshot: PathSnapshot { n, steps },
        created_unix,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(n: usize, k: usize) -> PathSnapshot {
        let steps = (0..=k)
            .map(|s| PathStep {
                lambda: (k + 1 - s) as f64,
                support: (0..s).collect(),
                coefs: (0..s).map(|j| j as f64 * 0.5 - 1.0).collect(),
                residual_norm: 1.0 / (s + 1) as f64,
            })
            .collect();
        PathSnapshot { n, steps }
    }

    fn meta(dataset: &str, t: usize) -> ModelMeta {
        ModelMeta {
            name: String::new(),
            algo: "lars".into(),
            dataset: dataset.into(),
            t,
            b: 1,
            p: 4,
            seed: 7,
            stop: "target_reached".into(),
            spec: format!("algo=lars t={t} tol=0.000000000001"),
            rows: 40,
            selection: "cp=2".into(),
        }
    }

    #[test]
    fn insert_get_roundtrip_and_versioning() {
        let reg = ModelRegistry::new(8);
        let id1 = reg.insert(meta("tiny", 3), snap(10, 3));
        let id2 = reg.insert(meta("tiny", 5), snap(10, 5));
        assert_ne!(id1, id2);
        assert_eq!(reg.get(id1).unwrap().version, 1);
        assert_eq!(reg.get(id2).unwrap().version, 2, "same family bumps version");
        let other = reg.insert(meta("year", 3), snap(10, 3));
        assert_eq!(reg.get(other).unwrap().version, 1, "new family restarts at 1");
    }

    #[test]
    fn insert_many_assigns_contiguous_ids_and_versions() {
        let reg = ModelRegistry::new(8);
        let before = reg.insert(meta("tiny", 3), snap(10, 3));
        let ids = reg.insert_many(vec![
            (meta("tiny", 5), snap(10, 5)),
            (meta("year", 3), snap(10, 3)),
            (meta("tiny", 7), snap(10, 7)),
        ]);
        assert_eq!(ids.len(), 3);
        assert_eq!(ids[1], ids[0] + 1, "batch ids are contiguous");
        assert_eq!(ids[2], ids[1] + 1);
        assert!(ids[0] > before);
        assert_eq!(reg.get(ids[0]).unwrap().version, 2, "family version bumps in-batch");
        assert_eq!(reg.get(ids[1]).unwrap().version, 1);
        assert_eq!(reg.get(ids[2]).unwrap().version, 3);
        assert_eq!(reg.stats().inserted, 4);
        assert!(reg.insert_many(Vec::new()).is_empty(), "empty batch is a no-op");
    }

    #[test]
    fn insert_many_respects_capacity() {
        let reg = ModelRegistry::new(2);
        let ids = reg.insert_many(vec![
            (meta("a", 2), snap(4, 2)),
            (meta("b", 2), snap(4, 2)),
            (meta("c", 2), snap(4, 2)),
        ]);
        assert_eq!(reg.len(), 2, "over-capacity batch evicts down to capacity");
        assert!(reg.get(ids[0]).is_none(), "oldest batch member evicted first");
        assert!(reg.get(ids[1]).is_some());
        assert!(reg.get(ids[2]).is_some());
    }

    #[test]
    fn lru_eviction_prefers_untouched() {
        let reg = ModelRegistry::new(2);
        let a = reg.insert(meta("a", 2), snap(4, 2));
        let b = reg.insert(meta("b", 2), snap(4, 2));
        reg.get(a); // a is now more recently used than b
        let c = reg.insert(meta("c", 2), snap(4, 2));
        assert!(reg.get(b).is_none(), "least-recently-used model evicted");
        assert!(reg.get(a).is_some());
        assert!(reg.get(c).is_some());
        assert_eq!(reg.stats().evicted, 1);
    }

    #[test]
    fn warm_start_finds_covering_path_only() {
        let reg = ModelRegistry::new(8);
        reg.insert(meta("tiny", 6), snap(10, 6));
        let m = meta("tiny", 4);
        assert!(reg.find_warm(&m, 4).is_some(), "stored path covers t=4");
        assert!(reg.find_warm(&m, 9).is_none(), "stored path too short for t=9");
        let mut other_algo = meta("tiny", 4);
        other_algo.algo = "blars".into();
        assert!(reg.find_warm(&other_algo, 2).is_none(), "different family");
        let mut other_p = meta("tiny", 4);
        other_p.p = 16;
        assert!(
            reg.find_warm(&other_p, 2).is_none(),
            "different rank count is a different family (T-bLARS selections depend on p)"
        );
        assert_eq!(reg.stats().warm_reused, 1);
    }

    #[test]
    fn record_binary_roundtrip_is_bit_exact() {
        let rec = ModelRecord {
            id: 42,
            version: 3,
            meta: meta("sector", 5),
            snapshot: snap(100, 5),
            created_unix: 1_700_000_000,
        };
        let mut buf = Vec::new();
        write_record(&mut buf, &rec).unwrap();
        let back = read_record(&mut buf.as_slice()).unwrap();
        assert_eq!(back.id, rec.id);
        assert_eq!(back.version, rec.version);
        assert_eq!(back.meta, rec.meta);
        assert_eq!(back.snapshot, rec.snapshot, "f64 payload must round-trip bit-exactly");
    }

    #[test]
    fn raw_rank_tokens_do_not_fragment_families() {
        // p=5 and p=8 requests both fit with 8 effective ranks (the
        // normalized `p` field): the raw ranks= token must not split
        // the family and defeat warm-start reuse.
        let reg = ModelRegistry::new(8);
        let mut first = meta("tiny", 6);
        first.algo = "blars".into();
        first.spec = "algo=blars t=6 tol=0.000000000001 b=1 ranks=5".into();
        reg.insert(first, snap(10, 6));
        let mut second = meta("tiny", 4);
        second.algo = "blars".into();
        second.spec = "algo=blars t=4 tol=0.000000000001 b=1 ranks=8".into();
        assert!(
            reg.find_warm(&second, 4).is_some(),
            "normalized-equal rank requests share a family"
        );
    }

    #[test]
    fn differing_non_t_spec_knobs_break_the_family() {
        // Same dataset/algo/b/p/seed but a different tol (or any other
        // non-`t` spec knob) selects a different path — it must not be
        // warm-reused.
        let reg = ModelRegistry::new(8);
        reg.insert(meta("tiny", 6), snap(10, 6));
        let mut loose = meta("tiny", 4);
        loose.spec = "algo=lars t=4 tol=0.5".to_string();
        assert!(
            reg.find_warm(&loose, 4).is_none(),
            "different tol must be a different family"
        );
        let mut same = meta("tiny", 4);
        same.spec = "algo=lars t=4 tol=0.000000000001".into();
        assert!(reg.find_warm(&same, 4).is_some(), "only t may differ within a family");
    }

    #[test]
    fn lasso_fits_never_form_a_warm_family() {
        let reg = ModelRegistry::new(8);
        let mut m = meta("tiny", 6);
        m.algo = "lasso".into();
        reg.insert(m.clone(), snap(10, 6));
        assert!(
            reg.find_warm(&m, 4).is_none(),
            "λ-truncated paths must not be warm-reused"
        );
    }

    #[test]
    fn reads_format_1_files_with_empty_stop_and_spec() {
        // Hand-build a format-1 record (no stop/spec strings).
        let rec = ModelRecord {
            id: 5,
            version: 1,
            meta: meta("legacy", 2),
            snapshot: snap(6, 2),
            created_unix: 1_700_000_000,
        };
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        w_u32(&mut buf, 1).unwrap(); // format 1
        w_u64(&mut buf, rec.id).unwrap();
        w_u32(&mut buf, rec.version).unwrap();
        w_u64(&mut buf, rec.created_unix).unwrap();
        w_str(&mut buf, &rec.meta.name).unwrap();
        w_str(&mut buf, &rec.meta.algo).unwrap();
        w_str(&mut buf, &rec.meta.dataset).unwrap();
        w_u64(&mut buf, rec.meta.t as u64).unwrap();
        w_u64(&mut buf, rec.meta.b as u64).unwrap();
        w_u64(&mut buf, rec.meta.p as u64).unwrap();
        w_u64(&mut buf, rec.meta.seed).unwrap();
        w_u64(&mut buf, rec.snapshot.n as u64).unwrap();
        w_u64(&mut buf, rec.snapshot.steps.len() as u64).unwrap();
        for step in &rec.snapshot.steps {
            w_f64(&mut buf, step.lambda).unwrap();
            w_f64(&mut buf, step.residual_norm).unwrap();
            w_u64(&mut buf, step.support.len() as u64).unwrap();
            for &j in &step.support {
                w_u64(&mut buf, j as u64).unwrap();
            }
            for &v in &step.coefs {
                w_f64(&mut buf, v).unwrap();
            }
        }
        let back = read_record(&mut buf.as_slice()).unwrap();
        assert_eq!(back.id, rec.id);
        assert_eq!(back.snapshot, rec.snapshot);
        assert_eq!(back.meta.dataset, "legacy");
        assert_eq!(back.meta.stop, "", "format-1 files have no stop reason");
        assert_eq!(back.meta.spec, "");
        assert_eq!(back.meta.rows, 0, "format-1 files have no row count");
        assert_eq!(back.meta.selection, "");
    }

    #[test]
    fn reads_format_2_files_with_empty_selection() {
        // Format 2 (pre-model-selection) carries stop/spec but neither
        // the training row count nor selection tokens.
        let rec = ModelRecord {
            id: 6,
            version: 1,
            meta: meta("legacy2", 2),
            snapshot: snap(6, 2),
            created_unix: 1_700_000_000,
        };
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        w_u32(&mut buf, 2).unwrap(); // format 2
        w_u64(&mut buf, rec.id).unwrap();
        w_u32(&mut buf, rec.version).unwrap();
        w_u64(&mut buf, rec.created_unix).unwrap();
        w_str(&mut buf, &rec.meta.name).unwrap();
        w_str(&mut buf, &rec.meta.algo).unwrap();
        w_str(&mut buf, &rec.meta.dataset).unwrap();
        w_u64(&mut buf, rec.meta.t as u64).unwrap();
        w_u64(&mut buf, rec.meta.b as u64).unwrap();
        w_u64(&mut buf, rec.meta.p as u64).unwrap();
        w_u64(&mut buf, rec.meta.seed).unwrap();
        w_str(&mut buf, &rec.meta.stop).unwrap();
        w_str(&mut buf, &rec.meta.spec).unwrap();
        w_u64(&mut buf, rec.snapshot.n as u64).unwrap();
        w_u64(&mut buf, rec.snapshot.steps.len() as u64).unwrap();
        for step in &rec.snapshot.steps {
            w_f64(&mut buf, step.lambda).unwrap();
            w_f64(&mut buf, step.residual_norm).unwrap();
            w_u64(&mut buf, step.support.len() as u64).unwrap();
            for &j in &step.support {
                w_u64(&mut buf, j as u64).unwrap();
            }
            for &v in &step.coefs {
                w_f64(&mut buf, v).unwrap();
            }
        }
        let back = read_record(&mut buf.as_slice()).unwrap();
        assert_eq!(back.snapshot, rec.snapshot);
        assert_eq!(back.meta.stop, rec.meta.stop);
        assert_eq!(back.meta.rows, 0, "format-2 files have no row count");
        assert_eq!(back.meta.selection, "");
    }

    #[test]
    fn record_selection_upserts_atomically_and_persists() {
        let dir = std::env::temp_dir().join(format!(
            "calars-store-sel-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let reg = ModelRegistry::with_persist_dir(&dir, 4).unwrap();
            // The meta() helper seeds selection = "cp=2".
            let id = reg.insert(meta("a", 2), snap(4, 2));
            assert!(reg.record_selection(id, "cv5.0", 1));
            assert_eq!(reg.get(id).unwrap().meta.selection, "cp=2 cv5.0=1");
            assert!(reg.record_selection(id, "cp", 3), "same key replaces");
            assert_eq!(reg.get(id).unwrap().meta.selection, "cv5.0=1 cp=3");
            assert!(!reg.record_selection(9999, "cp", 1), "unknown id refused");
        }
        let back = ModelRegistry::with_persist_dir(&dir, 4).unwrap();
        assert_eq!(
            back.list()[0].meta.selection,
            "cv5.0=1 cp=3",
            "selection survives the write-through restart"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn find_warm_skips_records_without_a_row_count() {
        // Legacy (format ≤ 2) records load with rows = 0; reusing them
        // would leave the in-sample criteria permanently unanswerable.
        let reg = ModelRegistry::new(8);
        let mut legacy = meta("tiny", 6);
        legacy.rows = 0;
        reg.insert(legacy, snap(10, 6));
        assert!(
            reg.find_warm(&meta("tiny", 4), 4).is_none(),
            "rows=0 record must be refitted, not warm-reused"
        );
        reg.insert(meta("tiny", 6), snap(10, 6));
        assert!(reg.find_warm(&meta("tiny", 4), 4).is_some(), "rows>0 record reused");
    }

    #[test]
    fn rejects_garbage() {
        assert!(read_record(&mut &b"NOPE"[..]).is_err());
        let mut buf = Vec::new();
        let rec = ModelRecord {
            id: 1,
            version: 1,
            meta: meta("x", 1),
            snapshot: snap(2, 1),
            created_unix: 0,
        };
        write_record(&mut buf, &rec).unwrap();
        buf.truncate(buf.len() - 3);
        assert!(read_record(&mut buf.as_slice()).is_err(), "truncated file fails");
    }

    #[test]
    fn write_through_persistence_survives_without_graceful_shutdown() {
        let dir = std::env::temp_dir()
            .join(format!("calars-store-wt-{}-{:?}", std::process::id(), std::thread::current().id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let reg = ModelRegistry::with_persist_dir(&dir, 2).unwrap();
            reg.insert(meta("a", 2), snap(4, 2));
            let b = reg.insert(meta("b", 2), snap(4, 2));
            // No save_dir, no drop hook — simulate a hard kill by just
            // abandoning the registry. Write-through already persisted.
            assert!(ModelRegistry::record_path(&dir, b).is_file());
            // Eviction deletes its file.
            let c = reg.insert(meta("c", 2), snap(4, 2));
            assert!(!ModelRegistry::record_path(&dir, 1).is_file(), "evicted file removed");
            assert!(ModelRegistry::record_path(&dir, c).is_file());
            // remove() deletes too.
            assert!(reg.remove(c));
            assert!(!ModelRegistry::record_path(&dir, c).is_file());
        }
        let back = ModelRegistry::with_persist_dir(&dir, 2).unwrap();
        assert_eq!(back.len(), 1, "exactly the surviving model reloads");
        assert!(back.list()[0].meta.dataset == "b");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_out_of_range_support_index() {
        // A support index ≥ n must fail at load time, not panic the
        // serving batcher at first predict.
        let mut rec = ModelRecord {
            id: 1,
            version: 1,
            meta: meta("x", 1),
            snapshot: snap(4, 2),
            created_unix: 0,
        };
        rec.snapshot.steps[2].support[1] = 99; // n = 4
        let mut buf = Vec::new();
        write_record(&mut buf, &rec).unwrap();
        let err = read_record(&mut buf.as_slice()).unwrap_err();
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
    }
}
