//! L4 — the model-serving subsystem.
//!
//! Everything needed to run a fitted-path inference service on top of
//! the LARS family, with zero external dependencies (see DESIGN.md
//! §"L4 — serving"):
//!
//! * [`store`] — [`ModelRegistry`]: versioned in-memory + on-disk
//!   storage of [`crate::lars::path::PathSnapshot`]s, LRU-bounded, with
//!   warm-start reuse (a fit whose family already has a covering path
//!   is free).
//! * [`engine`] — [`PredictionEngine`]: evaluate any stored path at an
//!   arbitrary step or λ (piecewise-linear between breakpoints), with
//!   per-(model, selector) request batching through one dense GEMV and
//!   an LRU coefficient-snapshot cache. Exactness contract: at stored
//!   breakpoints, served predictions are bit-identical to evaluating
//!   the fitter's coefficients directly.
//! * [`queue`] — [`FitQueue`]: OS-thread worker pool running
//!   [`FitJob`]s (dataset bindings around validated
//!   [`crate::fit::FitSpec`]s) asynchronously through the estimator
//!   API — with a [`crate::fit::SnapshotObserver`] attached — and
//!   registering the results with their stop reasons.
//! * [`gram_cache`] — [`GramCache`]: per-dataset cache of the loaded
//!   dataset, its column norms, and previously materialized Gram
//!   panels ([`crate::kern::cache`]), bound around every fit so
//!   warm-started family refits skip the dominant recomputation.
//!   Fingerprint-validated: re-uploading a dataset name with different
//!   contents invalidates the entry.
//! * [`protocol`] — the hand-rolled line protocol + HTTP/1.1 framing +
//!   minimal JSON emission.
//! * [`http`] — the front end (`calars serve`): `/fit`, `/predict`,
//!   `/models`, `/stats` over `std::net::TcpListener`, with a
//!   cross-connection [`http::Batcher`].
//! * [`loadgen`] — the closed-loop load generator
//!   (`calars bench-serve`, `benches/serving.rs`).

pub mod engine;
pub mod gram_cache;
pub mod http;
pub mod loadgen;
pub mod protocol;
pub mod queue;
pub mod store;

pub use engine::{EngineStats, PredictionEngine, Query, Selector};
pub use gram_cache::{DatasetInfo, GramCache, GramCacheStats, NormSummary};
pub use http::{serve, spawn_server, ServeOptions, ServerHandle};
pub use loadgen::{run_load, LoadOptions, LoadReport, ServeClient};
pub use protocol::{FitRequest, PredictRequest};
pub use queue::{FitJob, FitQueue, JobState, QueueStats};
pub use store::{ModelMeta, ModelRecord, ModelRegistry, RegistryStats};
