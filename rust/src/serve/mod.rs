//! L4 — the model-serving subsystem.
//!
//! Everything needed to run a fitted-path inference service on top of
//! the LARS family, with zero external dependencies (see DESIGN.md
//! §"L4 — serving"):
//!
//! * [`store`] — [`ModelRegistry`]: versioned in-memory + on-disk
//!   storage of [`crate::lars::path::PathSnapshot`]s, LRU-bounded, with
//!   warm-start reuse (a fit whose family already has a covering path
//!   is free).
//! * [`engine`] — [`PredictionEngine`]: evaluate any stored path at an
//!   arbitrary step or λ (piecewise-linear between breakpoints), with
//!   per-(model, selector) request batching through one dense GEMV and
//!   an LRU coefficient-snapshot cache. Exactness contract: at stored
//!   breakpoints, served predictions are bit-identical to evaluating
//!   the fitter's coefficients directly.
//! * [`queue`] — [`FitQueue`]: OS-thread worker pool running
//!   [`FitJob`]s (dataset bindings around validated
//!   [`crate::fit::FitSpec`]s) asynchronously through the estimator
//!   API — with a [`crate::fit::SnapshotObserver`] attached — and
//!   registering the results with their stop reasons.
//! * [`gram_cache`] — [`GramCache`]: per-dataset cache of the loaded
//!   dataset, its column norms, and previously materialized Gram
//!   panels ([`crate::kern::cache`]), bound around every fit so
//!   warm-started family refits skip the dominant recomputation.
//!   Fingerprint-validated: re-uploading a dataset name with different
//!   contents invalidates the entry.
//! * [`protocol`] — the hand-rolled line protocol + HTTP/1.1 framing +
//!   minimal JSON emission.
//! * [`http`] — the front end (`calars serve`): `/fit`, `/predict`,
//!   `/select`, `/models`, `/datasets`, `/stats` over
//!   `std::net::TcpListener`, with a cross-connection
//!   [`http::Batcher`]. `/select` drives [`crate::select`] over the
//!   stored paths (in-sample criteria from the snapshot; CV fold
//!   refits through the [`GramCache`]).
//! * [`loadgen`] — the closed-loop load generator
//!   (`calars bench-serve`, `benches/serving.rs`).

pub mod engine;
pub mod gram_cache;
pub mod http;
pub mod loadgen;
pub mod protocol;
pub mod queue;
pub mod store;

/// Poison-recovering lock helpers shared by the serve layer.
///
/// A thread that panics while holding a `Mutex` poisons it; the old
/// `.lock().unwrap()` call sites then cascaded that one panic into
/// **every** later connection thread, turning a single bad request
/// into a dead server. Recovery is safe here because every serve-layer
/// critical section leaves its data structurally valid at each await
/// point (counters, vectors of queued work, state maps); the worst
/// case after recovery is one lost in-flight request, which is
/// reported to its caller as a typed 500 instead of an abort.
pub(crate) mod sync {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::{Condvar, Mutex, MutexGuard};

    /// Lock, recovering a poisoned mutex and counting the recovery
    /// (surfaced through `/stats`).
    pub fn lock_recover<'a, T>(m: &'a Mutex<T>, recoveries: &AtomicU64) -> MutexGuard<'a, T> {
        match m.lock() {
            Ok(g) => g,
            Err(e) => {
                recoveries.fetch_add(1, Ordering::Relaxed);
                e.into_inner()
            }
        }
    }

    /// `Condvar::wait` with the same recovery.
    pub fn wait_recover<'a, T>(
        cv: &Condvar,
        g: MutexGuard<'a, T>,
        recoveries: &AtomicU64,
    ) -> MutexGuard<'a, T> {
        match cv.wait(g) {
            Ok(g) => g,
            Err(e) => {
                recoveries.fetch_add(1, Ordering::Relaxed);
                e.into_inner()
            }
        }
    }
}

pub use engine::{EngineStats, PredictionEngine, Query, Selector};
pub use gram_cache::{DatasetInfo, GramCache, GramCacheStats, NormSummary};
pub use http::{serve, spawn_server, ServeOptions, ServerHandle};
pub use loadgen::{run_load, LoadOptions, LoadReport, ServeClient};
pub use protocol::{BatchFitRequest, FitRequest, PredictRequest, SelectRequest};
pub use queue::{FitJob, FitQueue, JobState, QueueStats};
pub use store::{ModelMeta, ModelRecord, ModelRegistry, RegistryStats};
