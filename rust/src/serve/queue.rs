//! Asynchronous fit jobs: a worker pool of OS threads that runs fits
//! off the request path — through the [`crate::fit`] estimator API —
//! and registers the resulting path snapshots.
//!
//! A `/fit` request enqueues a [`FitJob`] (a dataset binding around a
//! validated [`FitSpec`]) and immediately gets a job id; callers poll
//! [`FitQueue::state`] or block on [`FitQueue::wait`] (the HTTP layer's
//! `?wait=1`). Before fitting, the worker asks the registry for a
//! **warm start**: if the model family already has a stored path
//! covering the requested `t`, the job completes instantly against the
//! existing model — fitting a prefix of a path that is already on disk
//! is free. The fit itself runs with a
//! [`crate::fit::SnapshotObserver`] attached (the replacement for the
//! deleted `*_with_snapshot` entry points), and the resulting
//! [`StopReason`](crate::lars::StopReason) lands in the registry
//! metadata so `/models` can say why each path ended.

use super::gram_cache::GramCache;
use super::store::{ModelMeta, ModelRegistry};
use super::sync::lock_recover;
use crate::batch::SharedWork;
use crate::data::datasets;
use crate::error::Result;
use crate::fit::{Algorithm, FitSpec, Fitter, SnapshotObserver};
use crate::kern;
use crate::lars::path::PathSnapshot;
use crate::select::{self, Criterion};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One fit job: the estimator spec plus the serving-side bindings
/// (which dataset to load, the registered model's display name, the
/// dataset seed).
#[derive(Clone, Debug)]
pub struct FitJob {
    /// Display name for the registered model ("" → generated).
    pub name: String,
    /// Dataset name resolved through [`datasets::by_name`].
    pub dataset: String,
    /// Dataset generation seed.
    pub seed: u64,
    /// The validated estimator spec (algorithm + shared knobs).
    pub spec: FitSpec,
    /// Trace id the worker binds around the fit (0 = untraced). The
    /// HTTP layer stamps the request's trace here so the fit's phase
    /// spans land in the same `/trace/<id>` timeline.
    pub trace: u64,
}

impl Default for FitJob {
    fn default() -> Self {
        FitJob {
            name: String::new(),
            dataset: "tiny".to_string(),
            seed: 42,
            spec: FitSpec::new(Algorithm::Lars).t(16),
            trace: 0,
        }
    }
}

impl FitJob {
    fn meta(&self) -> ModelMeta {
        ModelMeta {
            name: self.name.clone(),
            algo: self.spec.algorithm.name().to_string(),
            dataset: self.dataset.clone(),
            t: self.spec.t,
            b: self.spec.algorithm.block(),
            // Normalized the same way the fit dispatch normalizes it,
            // so the warm-start family matches what actually gets
            // fitted.
            p: self.spec.effective_ranks(),
            seed: self.seed,
            stop: String::new(),
            spec: self.spec.encode(),
            rows: 0,
            selection: String::new(),
        }
    }
}

/// One bulk multi-response fit: `k` posted responses against one
/// dataset's design matrix, fitted in lockstep through
/// [`FitSpec::fit_batch`] and registered in one
/// [`ModelRegistry::insert_many`] transaction.
#[derive(Clone, Debug)]
pub struct BatchFitJob {
    /// One display name per response (same length as `responses`).
    pub names: Vec<String>,
    /// Dataset providing the design matrix (its own response vector
    /// is ignored — the posted panel replaces it).
    pub dataset: String,
    /// Dataset generation seed.
    pub seed: u64,
    /// The validated estimator spec shared by every response.
    pub spec: FitSpec,
    /// The response panel, one vector per model.
    pub responses: Vec<Vec<f64>>,
}

/// What [`FitQueue::run_batch`] returns.
#[derive(Clone, Debug)]
pub struct BatchOutcome {
    /// Registered model ids, aligned with the job's response order.
    pub models: Vec<u64>,
    /// What the lockstep fit amortized across the responses.
    pub shared: SharedWork,
    /// Wall-clock seconds for the whole batch fit.
    pub wall_secs: f64,
}

/// Lifecycle of a submitted job.
#[derive(Clone, Debug, PartialEq)]
pub enum JobState {
    Queued,
    Running,
    Done { model: u64, reused: bool, wall_secs: f64 },
    Failed { error: String },
}

impl JobState {
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done { .. } | JobState::Failed { .. })
    }

    /// Short status word for the JSON responses.
    pub fn word(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done { .. } => "done",
            JobState::Failed { .. } => "failed",
        }
    }
}

enum Work {
    /// (job id, job, enqueue instant — measured so the worker can
    /// record the queue-wait span and histogram).
    Job(u64, FitJob, Instant),
    Shutdown,
}

struct Shared {
    registry: Arc<ModelRegistry>,
    gram_cache: Arc<GramCache>,
    states: Mutex<HashMap<u64, JobState>>,
    cv: Condvar,
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    /// Poisoned-lock recoveries (a worker panicked inside a queue
    /// critical section; the queue kept serving).
    recoveries: AtomicU64,
}

/// Queue counters exposed through `/stats`.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueStats {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub in_flight: u64,
    pub lock_recoveries: u64,
}

/// Worker pool running fit jobs on OS threads.
pub struct FitQueue {
    shared: Arc<Shared>,
    /// Mutex-wrapped so `FitQueue` is `Sync` on every toolchain
    /// (`mpsc::Sender` only became `Sync` in later std versions).
    tx: Mutex<Sender<Work>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    next_job: AtomicU64,
    nworkers: usize,
    stopped: AtomicBool,
}

impl FitQueue {
    /// Start `workers` fit threads (≥ 1) over `registry`, with a
    /// default-sized [`GramCache`].
    pub fn new(registry: Arc<ModelRegistry>, workers: usize) -> Self {
        Self::with_gram_cache(registry, workers, Arc::new(GramCache::default()))
    }

    /// Start `workers` fit threads (≥ 1) over `registry`, binding
    /// `gram_cache` around every fit (the server shares one cache
    /// between the queue and `/stats`).
    pub fn with_gram_cache(
        registry: Arc<ModelRegistry>,
        workers: usize,
        gram_cache: Arc<GramCache>,
    ) -> Self {
        let nworkers = workers.max(1);
        let (tx, rx) = channel::<Work>();
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(Shared {
            registry,
            gram_cache,
            states: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            recoveries: AtomicU64::new(0),
        });
        let mut handles = Vec::with_capacity(nworkers);
        for widx in 0..nworkers {
            let rx = Arc::clone(&rx);
            let shared = Arc::clone(&shared);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("calars-fit-{widx}"))
                    .spawn(move || worker_loop(rx, shared))
                    // audit: allow(PANIC-UNWRAP) -- startup-time spawn: runs before the server accepts traffic, and a host that cannot spawn threads cannot serve
                    .expect("spawn fit worker"),
            );
        }
        FitQueue {
            shared,
            tx: Mutex::new(tx),
            workers: Mutex::new(handles),
            next_job: AtomicU64::new(1),
            nworkers,
            stopped: AtomicBool::new(false),
        }
    }

    /// Enqueue a job; returns its id immediately. After shutdown the
    /// job is marked Failed instead of queued.
    pub fn submit(&self, job: FitJob) -> u64 {
        let id = self.next_job.fetch_add(1, Ordering::Relaxed);
        lock_recover(&self.shared.states, &self.shared.recoveries).insert(id, JobState::Queued);
        self.shared.submitted.fetch_add(1, Ordering::Relaxed);
        let sent = !self.stopped.load(Ordering::SeqCst)
            && lock_recover(&self.tx, &self.shared.recoveries)
                .send(Work::Job(id, job, Instant::now()))
                .is_ok();
        if !sent {
            self.fail_job(id, "fit queue is shut down");
        }
        id
    }

    fn fail_job(&self, id: u64, error: &str) {
        let mut st = lock_recover(&self.shared.states, &self.shared.recoveries);
        let terminal = st.get(&id).map_or(false, JobState::is_terminal);
        if !terminal {
            st.insert(id, JobState::Failed { error: error.to_string() });
            self.shared.failed.fetch_add(1, Ordering::Relaxed);
        }
        drop(st);
        self.shared.cv.notify_all();
    }

    /// Current state of a job (None = unknown id).
    pub fn state(&self, job: u64) -> Option<JobState> {
        lock_recover(&self.shared.states, &self.shared.recoveries).get(&job).cloned()
    }

    /// Block until the job reaches a terminal state or `timeout`
    /// elapses; returns the last observed state.
    pub fn wait(&self, job: u64, timeout: Duration) -> Option<JobState> {
        let deadline = Instant::now() + timeout;
        let mut st = lock_recover(&self.shared.states, &self.shared.recoveries);
        loop {
            match st.get(&job) {
                None => return None,
                Some(s) if s.is_terminal() => return Some(s.clone()),
                Some(_) => {}
            }
            let now = Instant::now();
            if now >= deadline {
                return st.get(&job).cloned();
            }
            let (guard, _) = match self.shared.cv.wait_timeout(st, deadline - now) {
                Ok(r) => r,
                Err(e) => {
                    self.shared.recoveries.fetch_add(1, Ordering::Relaxed);
                    e.into_inner()
                }
            };
            st = guard;
        }
    }

    /// The Gram/norm cache bound around this queue's fits (shared with
    /// `/stats`).
    pub fn gram_cache(&self) -> &Arc<GramCache> {
        &self.shared.gram_cache
    }

    /// Run one bulk multi-response fit **synchronously on the calling
    /// thread** (the HTTP layer calls this from the connection thread,
    /// which is exactly as blocking as `/fit?wait=1`): resolve the
    /// dataset through the [`GramCache`], run
    /// [`FitSpec::fit_batch`] under its panel-store binding so every
    /// model in the batch shares the cross-fit Gram panels, snapshot
    /// each fitted path, and register all `k` models in one
    /// [`ModelRegistry::insert_many`] transaction. The whole batch is
    /// wrapped in a `serve_batch_fit` span and counted in the
    /// `calars_batch_*` metrics.
    ///
    /// Batch models never join a warm-start family with ordinary fits:
    /// their spec string carries a fingerprint of the posted response
    /// (`batch=<hash>`), so only a byte-identical re-post would match —
    /// an ordinary `/fit` of the same dataset must not be answered by a
    /// path fitted against someone's custom response panel.
    pub fn run_batch(&self, job: &BatchFitJob) -> Result<BatchOutcome> {
        if job.names.len() != job.responses.len() {
            crate::bail!(
                "batch has {} names for {} responses",
                job.names.len(),
                job.responses.len()
            );
        }
        let span = crate::obs::span("serve_batch_fit");
        let (ds, store) = match self.shared.gram_cache.lookup(&job.dataset, job.seed) {
            Some(hit) => hit,
            None => {
                let ds = Arc::new(
                    datasets::by_name(&job.dataset, job.seed)
                        .ok_or_else(|| crate::anyhow!("unknown dataset '{}'", job.dataset))?,
                );
                let store =
                    self.shared.gram_cache.register(&job.dataset, job.seed, Arc::clone(&ds));
                (ds, store)
            }
        };
        let batch =
            kern::cache::with_store(&store, || job.spec.fit_batch(&ds.a, &job.responses))?;
        let spec_str = job.spec.encode();
        let mut entries = Vec::with_capacity(batch.fits.len());
        for (i, fit) in batch.fits.iter().enumerate() {
            let snapshot = match &fit.lasso {
                Some(path) => PathSnapshot::from_lasso(ds.a.ncols(), path),
                None => PathSnapshot::from_fit(&ds.a, &job.responses[i], &fit.output.selected),
            };
            let mut meta = ModelMeta {
                name: job.names[i].clone(),
                algo: job.spec.algorithm.name().to_string(),
                dataset: job.dataset.clone(),
                t: job.spec.t,
                b: job.spec.algorithm.block(),
                p: job.spec.effective_ranks(),
                seed: job.seed,
                stop: fit.output.stop.word().to_string(),
                spec: format!("{spec_str} batch={:016x}", response_fingerprint(&job.responses[i])),
                rows: ds.a.nrows(),
                selection: String::new(),
            };
            for c in [Criterion::Cp, Criterion::Aic, Criterion::Bic] {
                if let Ok(sel) = select::rank_steps(&snapshot, meta.rows, c) {
                    meta.selection =
                        select::upsert_selection(&meta.selection, c.name(), sel.best_step);
                }
            }
            entries.push((meta, snapshot));
        }
        let models = self.shared.registry.insert_many(entries);
        drop(span);
        let reg = crate::obs::global();
        reg.counter("calars_batch_fits_total", "", "Bulk fit batches executed.").inc();
        reg.counter("calars_batch_responses_total", "", "Responses fitted through bulk batches.")
            .add(batch.shared.responses as u64);
        reg.counter(
            "calars_batch_passes_saved_total",
            "",
            "Matrix passes avoided by lockstep batching vs sequential fits.",
        )
        .add(batch.shared.passes_saved());
        Ok(BatchOutcome { models, shared: batch.shared, wall_secs: batch.wall_secs })
    }

    /// Counter snapshot for `/stats`.
    pub fn stats(&self) -> QueueStats {
        let submitted = self.shared.submitted.load(Ordering::Relaxed);
        let completed = self.shared.completed.load(Ordering::Relaxed);
        let failed = self.shared.failed.load(Ordering::Relaxed);
        QueueStats {
            submitted,
            completed,
            failed,
            in_flight: submitted.saturating_sub(completed + failed),
            lock_recoveries: self.shared.recoveries.load(Ordering::Relaxed),
        }
    }

    /// Stop accepting work and join all workers (idempotent).
    pub fn shutdown(&self) {
        if self.stopped.swap(true, Ordering::SeqCst) {
            return;
        }
        {
            let tx = lock_recover(&self.tx, &self.shared.recoveries);
            for _ in 0..self.nworkers {
                let _ = tx.send(Work::Shutdown);
            }
        }
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *lock_recover(&self.workers, &self.shared.recoveries));
        for h in handles {
            let _ = h.join();
        }
        // A submit racing the sentinel sends can land its job *behind*
        // them, where no worker will ever pop it; fail every job still
        // non-terminal so waiters wake instead of running out the clock.
        let stuck: Vec<u64> = {
            let st = lock_recover(&self.shared.states, &self.shared.recoveries);
            st.iter().filter(|(_, s)| !s.is_terminal()).map(|(&id, _)| id).collect()
        };
        for id in stuck {
            self.fail_job(id, "fit queue is shut down");
        }
    }
}

impl Drop for FitQueue {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Work>>>, shared: Arc<Shared>) {
    loop {
        // Hold the lock only for the blocking recv (the book's thread
        // pool pattern): once a message arrives the guard drops and the
        // next idle worker can take the receiver.
        let work = {
            let guard = lock_recover(&rx, &shared.recoveries);
            guard.recv()
        };
        let (job, spec, enqueued) = match work {
            Ok(Work::Job(job, spec, enqueued)) => (job, spec, enqueued),
            Ok(Work::Shutdown) | Err(_) => return,
        };
        set_state(&shared, job, JobState::Running);
        let t0 = Instant::now();
        let wait = enqueued.elapsed();
        queue_wait_histogram().observe_secs(wait);
        // A panic inside the fit must fail this one job, not silently
        // shrink the worker pool (and strand the job in Running). The
        // trace binding sits *inside* catch_unwind so its reset guard
        // runs (and the thread's span buffer flushes) even on panic.
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            crate::obs::with_trace(spec.trace, || {
                crate::obs::record_span_ending_now(
                    "queue_wait",
                    Some(crate::cluster::tracer::Phase::Wait),
                    wait.as_nanos() as u64,
                );
                run_fit(&shared.registry, &shared.gram_cache, &spec)
            })
        }));
        let state = match outcome {
            Ok(Ok((model, reused))) => {
                shared.completed.fetch_add(1, Ordering::Relaxed);
                JobState::Done { model, reused, wall_secs: t0.elapsed().as_secs_f64() }
            }
            Ok(Err(e)) => {
                shared.failed.fetch_add(1, Ordering::Relaxed);
                JobState::Failed { error: format!("{e:#}") }
            }
            Err(panic) => {
                shared.failed.fetch_add(1, Ordering::Relaxed);
                let what = panic
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "unknown panic".to_string());
                JobState::Failed { error: format!("fit worker panicked: {what}") }
            }
        };
        set_state(&shared, job, state);
    }
}

/// FNV-1a over a response vector's f64 bits — the identity token that
/// keeps batch-fitted models out of ordinary warm-start families (see
/// [`FitQueue::run_batch`]).
fn response_fingerprint(b: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for v in b {
        for byte in v.to_bits().to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Queue-wait latency histogram in the global metrics registry,
/// registered once and cloned thereafter (observing is lock-free).
fn queue_wait_histogram() -> crate::obs::Histogram {
    static H: std::sync::OnceLock<crate::obs::Histogram> = std::sync::OnceLock::new();
    H.get_or_init(|| {
        crate::obs::global().histogram(
            "calars_fit_queue_wait_seconds",
            "",
            "Time fit jobs spent queued before a worker picked them up.",
            &crate::obs::latency_bounds(),
        )
    })
    .clone()
}

fn set_state(shared: &Shared, job: u64, state: JobState) {
    lock_recover(&shared.states, &shared.recoveries).insert(job, state);
    shared.cv.notify_all();
}

/// Execute one fit: warm-start check → dataset through the
/// [`GramCache`] (cached load + panel store) → estimator API with a
/// snapshot observer, run under the dataset's panel-store binding so
/// `gram_block` calls hit the cross-fit cache → register. Returns
/// (model id, warm-reused?).
fn run_fit(
    registry: &Arc<ModelRegistry>,
    gram_cache: &Arc<GramCache>,
    job: &FitJob,
) -> Result<(u64, bool)> {
    let mut meta = job.meta();
    if let Some(rec) = registry.find_warm(&meta, job.spec.t) {
        return Ok((rec.id, true));
    }
    let (ds, store) = match gram_cache.lookup(&job.dataset, job.seed) {
        Some(hit) => hit,
        None => {
            let ds = Arc::new(
                datasets::by_name(&job.dataset, job.seed)
                    .ok_or_else(|| crate::anyhow!("unknown dataset '{}'", job.dataset))?,
            );
            let store = gram_cache.register(&job.dataset, job.seed, Arc::clone(&ds));
            (ds, store)
        }
    };
    let mut snap = SnapshotObserver::new();
    let result = kern::cache::with_store(&store, || job.spec.fit(&ds.a, &ds.b, &mut snap))?;
    meta.stop = result.output.stop.word().to_string();
    meta.rows = ds.a.nrows();
    // on_complete always fires when fit() returns Ok, so the snapshot
    // is always captured; a miss is an internal contract violation,
    // reported as a typed error rather than a worker panic.
    let snapshot = snap
        .into_snapshot()
        .ok_or_else(|| crate::error::Error::internal("fit returned Ok without a path snapshot"))?;
    // Precompute the in-sample selection tokens so /models can say
    // which step each criterion serves without a separate pass; CV
    // tokens land later via POST /select.
    for c in [Criterion::Cp, Criterion::Aic, Criterion::Bic] {
        if let Ok(sel) = select::rank_steps(&snapshot, meta.rows, c) {
            meta.selection = select::upsert_selection(&meta.selection, c.name(), sel.best_step);
        }
    }
    Ok((registry.insert(meta, snapshot), false))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn queue() -> FitQueue {
        FitQueue::new(Arc::new(ModelRegistry::new(16)), 2)
    }

    fn lars_job(t: usize) -> FitJob {
        FitJob { spec: FitSpec::new(Algorithm::Lars).t(t), ..Default::default() }
    }

    #[test]
    fn fit_job_completes_and_registers() {
        let q = queue();
        let job = q.submit(lars_job(6));
        let state = q.wait(job, Duration::from_secs(60)).expect("job known");
        let (model, reused) = match state {
            JobState::Done { model, reused, .. } => (model, reused),
            other => panic!("expected Done, got {other:?}"),
        };
        assert!(!reused);
        let rec = q.shared.registry.get(model).expect("model registered");
        assert_eq!(rec.snapshot.max_support(), 6);
        assert_eq!(rec.meta.dataset, "tiny");
        assert_eq!(rec.meta.stop, "target_reached", "stop reason lands in metadata");
        assert!(rec.meta.spec.contains("algo=lars"), "{}", rec.meta.spec);
    }

    #[test]
    fn second_smaller_fit_is_warm_reused() {
        let q = queue();
        let j1 = q.submit(lars_job(8));
        let s1 = q.wait(j1, Duration::from_secs(60)).unwrap();
        let m1 = match s1 {
            JobState::Done { model, .. } => model,
            other => panic!("first fit should finish: {other:?}"),
        };
        let j2 = q.submit(lars_job(4));
        let s2 = q.wait(j2, Duration::from_secs(60)).unwrap();
        let (m2, reused) = match s2 {
            JobState::Done { model, reused, .. } => (model, reused),
            other => panic!("second fit should finish: {other:?}"),
        };
        assert!(reused, "covering path must be reused");
        assert_eq!(m1, m2);
    }

    #[test]
    fn unknown_dataset_fails_cleanly() {
        let q = queue();
        let job = q.submit(FitJob { dataset: "no-such-data".into(), ..Default::default() });
        let state = q.wait(job, Duration::from_secs(60)).unwrap();
        let error = match state {
            JobState::Failed { error } => error,
            other => panic!("expected failure, got {other:?}"),
        };
        assert!(error.contains("no-such-data"));
        assert_eq!(q.stats().failed, 1);
    }

    #[test]
    fn invalid_spec_fails_cleanly_instead_of_panicking() {
        let q = queue();
        let job = q.submit(FitJob {
            spec: FitSpec::new(Algorithm::Blars { b: 0 }).t(6),
            ..Default::default()
        });
        let state = q.wait(job, Duration::from_secs(60)).unwrap();
        assert!(
            matches!(state, JobState::Failed { .. }),
            "zero block size must fail the job, not kill the worker: {state:?}"
        );
        // The worker thread survived; a valid job still completes.
        let ok = q.submit(lars_job(4));
        let state = q.wait(ok, Duration::from_secs(60)).unwrap();
        assert!(matches!(state, JobState::Done { .. }), "{state:?}");
    }

    #[test]
    fn blars_tblars_and_lasso_fit_through_the_queue() {
        let q = queue();
        let jb = q.submit(FitJob {
            spec: FitSpec::new(Algorithm::Blars { b: 2 }).t(6).ranks(4),
            ..Default::default()
        });
        let jt = q.submit(FitJob {
            spec: FitSpec::new(Algorithm::TBlars { b: 2, parts: 4 }).t(6),
            ..Default::default()
        });
        let jl = q.submit(FitJob {
            spec: FitSpec::new(Algorithm::LassoLars { lambda_min: 1e-8 }).t(6),
            ..Default::default()
        });
        for job in [jb, jt, jl] {
            let state = q.wait(job, Duration::from_secs(120)).unwrap();
            assert!(
                matches!(state, JobState::Done { .. }),
                "job {job} should finish: {state:?}"
            );
        }
        assert_eq!(q.stats().completed, 3);
    }

    #[test]
    fn batch_fit_registers_models_without_polluting_warm_start() {
        let q = queue();
        let ds = datasets::by_name("tiny", 42).unwrap();
        let responses: Vec<Vec<f64>> = vec![
            ds.b.clone(),
            ds.b.iter().map(|v| -v).collect(),
            ds.b.iter().map(|v| 2.0 * v).collect(),
        ];
        let job = BatchFitJob {
            names: vec!["a".into(), "b".into(), "c".into()],
            dataset: "tiny".into(),
            seed: 42,
            spec: FitSpec::new(Algorithm::Lars).t(6),
            responses,
        };
        let out = q.run_batch(&job).unwrap();
        assert_eq!(out.models.len(), 3);
        assert_eq!(out.shared.responses, 3);
        assert!(out.shared.passes_saved() > 0, "{:?}", out.shared);
        for (&id, name) in out.models.iter().zip(["a", "b", "c"]) {
            let rec = q.shared.registry.get(id).expect("batch member registered");
            assert_eq!(rec.meta.name, name);
            assert!(rec.meta.spec.contains("batch="), "{}", rec.meta.spec);
            assert!(rec.snapshot.len() > 0);
            assert!(
                select::find_selection(&rec.meta.selection, "cp").is_some(),
                "in-sample selection tokens precomputed for batch members"
            );
        }
        // An ordinary fit of the same family must rerun, not reuse a
        // path fitted against a posted response panel.
        let j = q.submit(lars_job(6));
        match q.wait(j, Duration::from_secs(60)).unwrap() {
            JobState::Done { reused, .. } => assert!(!reused, "batch must not warm-start fits"),
            other => panic!("{other:?}"),
        }
        // Mismatched names fail before any fitting starts.
        let bad = BatchFitJob { names: vec!["x".into()], ..job };
        assert!(q.run_batch(&bad).is_err());
        // Unknown datasets fail cleanly too.
        let lost = BatchFitJob {
            names: vec!["x".into()],
            dataset: "no-such-data".into(),
            seed: 1,
            spec: FitSpec::new(Algorithm::Lars).t(4),
            responses: vec![vec![1.0; 8]],
        };
        assert!(q.run_batch(&lost).unwrap_err().root().contains("no-such-data"));
    }

    #[test]
    fn state_unknown_job_is_none() {
        let q = queue();
        assert!(q.state(12345).is_none());
        assert!(q.wait(12345, Duration::from_millis(10)).is_none());
    }

    #[test]
    fn poisoned_state_lock_recovers_instead_of_cascading() {
        // Regression: a thread panicking inside the states critical
        // section used to poison the mutex, and every later
        // `.lock().unwrap()` — i.e. every later connection — panicked
        // too. The queue now recovers, counts it, and keeps serving.
        let q = Arc::new(queue());
        let job = q.submit(lars_job(4));
        assert!(matches!(
            q.wait(job, Duration::from_secs(60)).unwrap(),
            JobState::Done { .. }
        ));
        let q2 = Arc::clone(&q);
        let _ = std::thread::spawn(move || {
            let _guard = q2.shared.states.lock().unwrap();
            panic!("poison the states lock");
        })
        .join();
        // Pre-fix this call aborted the thread; now it answers.
        assert!(matches!(q.state(job), Some(JobState::Done { .. })));
        assert!(q.stats().lock_recoveries >= 1, "{:?}", q.stats());
        // The queue still runs new jobs end to end.
        let job2 = q.submit(lars_job(6));
        assert!(matches!(
            q.wait(job2, Duration::from_secs(60)).unwrap(),
            JobState::Done { .. }
        ));
    }

    #[test]
    fn fit_metadata_records_rows_and_in_sample_selection() {
        let q = queue();
        let job = q.submit(lars_job(8));
        let model = match q.wait(job, Duration::from_secs(60)).unwrap() {
            JobState::Done { model, .. } => model,
            other => panic!("{other:?}"),
        };
        let rec = q.shared.registry.get(model).unwrap();
        assert_eq!(rec.meta.rows, 120, "tiny has 120 rows");
        for key in ["cp", "aic", "bic"] {
            let step = select::find_selection(&rec.meta.selection, key);
            assert!(step.is_some(), "{key} token missing in '{}'", rec.meta.selection);
            assert!(step.unwrap() <= 8);
        }
    }

    #[test]
    fn warm_refit_hits_the_gram_cache() {
        // One worker so the two fits run strictly in order.
        let q = FitQueue::new(Arc::new(ModelRegistry::new(16)), 1);
        let j1 = q.submit(lars_job(4));
        assert!(matches!(
            q.wait(j1, Duration::from_secs(60)).unwrap(),
            JobState::Done { .. }
        ));
        let after_first = q.gram_cache().stats();
        assert_eq!(after_first.datasets, 1, "dataset registered on first fit");
        assert!(after_first.panels > 0, "first fit materialized Gram panels");
        // Deeper refit of the same family: the warm-start snapshot is
        // too short, so the fit reruns — and its selection prefix
        // repeats the same panel keys, which must now hit.
        let j2 = q.submit(lars_job(8));
        let s2 = q.wait(j2, Duration::from_secs(60)).unwrap();
        assert!(matches!(s2, JobState::Done { reused: false, .. }), "{s2:?}");
        let after_second = q.gram_cache().stats();
        assert_eq!(after_second.dataset_hits, 1, "dataset load skipped on refit");
        assert!(
            after_second.panel_hits > after_first.panel_hits,
            "warm refit must reuse cached Gram panels: {after_second:?}"
        );
    }
}
