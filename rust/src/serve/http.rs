//! Zero-dependency HTTP/1.1 front end over `std::net::TcpListener`.
//!
//! Endpoints:
//!
//! * `GET  /healthz` — liveness probe
//! * `GET  /models`  — registry listing (JSON)
//! * `GET  /datasets`— GramCache listing: cached datasets, their
//!   column-norm summaries (the training scale raw features must be
//!   divided by), and per-dataset panel counters (JSON)
//! * `GET  /stats`   — engine/queue/registry/gram-cache counters (JSON)
//! * `POST /fit`     — enqueue a fit job (`?wait=1` blocks until done);
//!   a body with `y` response rows switches to **bulk mode**: all
//!   posted responses fit one design matrix in a single
//!   [`crate::fit::FitSpec::fit_batch`] lockstep call and register as
//!   k models in one registry transaction
//! * `POST /predict` — batched prediction (line-protocol body)
//! * `POST /select`  — model selection on a stored path: Cp/AIC/BIC
//!   from the snapshot, or k-fold CV refits through the GramCache
//!   (line-protocol body; result cached in the model metadata)
//! * `POST /shutdown`— graceful stop (only with `allow_shutdown`, i.e.
//!   `calars serve --oneshot` and in-process test servers)
//!
//! Connections are keep-alive with one OS thread each; prediction rows
//! from **all** connections funnel into a shared [`Batcher`], whose
//! single drain thread sleeps a short accumulation window and then
//! evaluates everything that arrived as one
//! [`PredictionEngine::predict_batch`] call — concurrent clients
//! hitting the same model are answered by a single GEMV.

use super::engine::{PredictionEngine, Query};
use super::protocol::{
    self, http_response, json_escape, json_f64, BatchFitRequest, FitRequest, HttpRequest,
    PredictRequest, SelectRequest,
};
use super::queue::{BatchFitJob, FitJob, FitQueue, JobState};
use super::store::{ModelRecord, ModelRegistry, RegistryStats};
use super::sync::{lock_recover, wait_recover};
use crate::data::datasets::{self, Dataset};
use crate::error::{Context, Error, ErrorKind, Result};
use crate::fit::FitSpec;
use crate::kern;
use crate::select::{self, Criterion, SelectSpec, Selection};
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Server configuration (CLI mapping in [`crate::config::ServeConfig`]).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Bind address; port 0 picks an ephemeral port.
    pub addr: String,
    /// Fit worker threads.
    pub fit_workers: usize,
    /// Batch accumulation window in microseconds (0 = drain eagerly).
    pub batch_window_us: u64,
    /// Registry capacity (models held before LRU eviction).
    pub registry_capacity: usize,
    /// Coefficient-snapshot cache capacity (dense vectors).
    pub cache_capacity: usize,
    /// Honor `POST /shutdown` (oneshot smoke runs, in-process tests).
    pub allow_shutdown: bool,
    /// Load the registry from / save it to this directory.
    pub persist_dir: Option<String>,
    /// Fit this dataset synchronously before accepting traffic.
    pub prefit: Option<String>,
    /// Requests slower than this land in the ring-buffered slow-request
    /// log (`calars::obs::sink().slow_log()`).
    pub slow_ms: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            addr: "127.0.0.1:7878".to_string(),
            fit_workers: 2,
            batch_window_us: 200,
            registry_capacity: 64,
            cache_capacity: 256,
            allow_shutdown: false,
            persist_dir: None,
            prefit: None,
            slow_ms: 500,
        }
    }
}

impl From<crate::config::ServeConfig> for ServeOptions {
    fn from(c: crate::config::ServeConfig) -> Self {
        ServeOptions {
            addr: c.addr,
            fit_workers: c.fit_workers,
            batch_window_us: c.batch_window_us,
            registry_capacity: c.registry_capacity,
            cache_capacity: c.cache_capacity,
            allow_shutdown: c.oneshot,
            persist_dir: c.persist_dir,
            prefit: c.prefit,
            slow_ms: c.slow_ms,
        }
    }
}

struct ServerState {
    registry: Arc<ModelRegistry>,
    engine: Arc<PredictionEngine>,
    queue: FitQueue,
    batcher: Arc<Batcher>,
    /// Fold shards for cross-validated `/select` live in their own
    /// cache: registering k near-dataset-sized fold clones in the main
    /// [`super::GramCache`] would LRU-evict the real datasets it
    /// exists to keep (`/stats` → `cv_cache`).
    cv_cache: Arc<super::GramCache>,
    running: AtomicBool,
    allow_shutdown: bool,
    persist_dir: Option<PathBuf>,
    addr: SocketAddr,
    started: Instant,
    requests: AtomicU64,
    /// Slow-request threshold (requests over it land in the obs sink's
    /// ring-buffered slow log).
    slow: Duration,
}

/// Run the server on the current thread until shutdown.
pub fn serve(opts: &ServeOptions) -> Result<()> {
    let (listener, state) = bind(opts)?;
    println!("calars serve listening on {}", state.addr);
    accept_loop(listener, state);
    Ok(())
}

/// Handle to an in-process server (tests, benches, self-contained
/// `bench-serve`).
pub struct ServerHandle {
    pub addr: SocketAddr,
    join: thread::JoinHandle<()>,
}

impl ServerHandle {
    /// `host:port` string clients can connect to.
    pub fn addr_string(&self) -> String {
        self.addr.to_string()
    }

    /// Gracefully stop the server (POST /shutdown) and join it.
    pub fn stop(self) {
        if let Ok(mut c) = super::loadgen::ServeClient::connect(&self.addr.to_string()) {
            let _ = c.request("POST", "/shutdown", "");
        }
        let _ = self.join.join();
    }
}

/// Start a server on a background thread; always honors `/shutdown`.
pub fn spawn_server(opts: &ServeOptions) -> Result<ServerHandle> {
    let mut opts = opts.clone();
    opts.allow_shutdown = true;
    let (listener, state) = bind(&opts)?;
    let addr = state.addr;
    let join = thread::Builder::new()
        .name("calars-serve-accept".to_string())
        .spawn(move || accept_loop(listener, state))
        .context("spawn accept loop")?;
    Ok(ServerHandle { addr, join })
}

fn bind(opts: &ServeOptions) -> Result<(TcpListener, Arc<ServerState>)> {
    let registry = match &opts.persist_dir {
        // Write-through persistence: each completed fit lands on disk
        // immediately, so an ungraceful stop (SIGTERM/SIGKILL) loses
        // nothing that finished fitting.
        Some(dir) => Arc::new(
            ModelRegistry::with_persist_dir(std::path::Path::new(dir), opts.registry_capacity)
                .with_context(|| format!("open registry dir {dir}"))?,
        ),
        None => Arc::new(ModelRegistry::new(opts.registry_capacity)),
    };
    let engine = Arc::new(PredictionEngine::new(registry.clone(), opts.cache_capacity));
    let queue = FitQueue::new(registry.clone(), opts.fit_workers);
    if let Some(dataset) = &opts.prefit {
        let job = queue.submit(FitJob { dataset: dataset.clone(), ..Default::default() });
        match queue.wait(job, Duration::from_secs(600)) {
            Some(JobState::Done { model, .. }) => {
                println!("prefit '{dataset}' ready as model {model}");
            }
            other => crate::bail!("prefit of '{dataset}' did not complete: {other:?}"),
        }
    }
    let batcher = Batcher::start(engine.clone(), Duration::from_micros(opts.batch_window_us));
    let listener = TcpListener::bind(&opts.addr)
        .with_context(|| format!("bind {}", opts.addr))?;
    let addr = listener.local_addr().context("local_addr")?;
    let state = Arc::new(ServerState {
        registry,
        engine,
        queue,
        batcher,
        // Bounded well below the main cache: fold shards are cheap to
        // rebuild (one row gather) — only their Gram panels are worth
        // keeping across selections.
        cv_cache: Arc::new(super::GramCache::new(32, 8 << 20).dataset_byte_bound(64 << 20)),
        running: AtomicBool::new(true),
        allow_shutdown: opts.allow_shutdown,
        persist_dir: opts.persist_dir.as_ref().map(PathBuf::from),
        addr,
        started: Instant::now(),
        requests: AtomicU64::new(0),
        slow: Duration::from_millis(opts.slow_ms),
    });
    Ok((listener, state))
}

fn accept_loop(listener: TcpListener, state: Arc<ServerState>) {
    for stream in listener.incoming() {
        if !state.running.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        let st = Arc::clone(&state);
        let _ = thread::Builder::new()
            .name("calars-serve-conn".to_string())
            .spawn(move || handle_connection(stream, st));
    }
    state.batcher.stop();
    state.queue.shutdown();
    // Inserts already wrote through; this final sweep is a consistency
    // belt-and-braces for graceful shutdowns.
    if let Some(dir) = &state.persist_dir {
        match state.registry.save_dir(dir) {
            Ok(nmodels) => println!("registry persisted: {nmodels} models → {}", dir.display()),
            Err(e) => eprintln!("registry persist failed: {e:#}"),
        }
    }
}

fn handle_connection(stream: TcpStream, state: Arc<ServerState>) {
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    loop {
        let req = match protocol::read_http_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => return, // clean EOF
            Err(e) => {
                let body = format!("{{\"error\":\"{}\"}}", json_escape(&format!("{e:#}")));
                let _ = writer.write_all(http_response(400, "application/json", &body).as_bytes());
                return;
            }
        };
        state.requests.fetch_add(1, Ordering::Relaxed);
        // Every request gets a trace id. Spans recorded while handling
        // it — including fit phases run later by a queue worker that
        // inherits the id through FitJob.trace — surface at
        // `GET /trace/<id>`; the id is echoed in the JSON response.
        let trace = crate::obs::next_trace_id();
        let t0 = Instant::now();
        let (status, ctype, mut body) = crate::obs::with_trace(trace, || {
            let span = crate::obs::span("http_request");
            let out = route(&req, &state);
            drop(span);
            out
        });
        let elapsed = t0.elapsed();
        request_histogram(route_label(&req.method, &req.path)).observe_secs(elapsed);
        if !state.slow.is_zero() && elapsed >= state.slow {
            crate::obs::sink().note_slow(
                trace,
                format!("{} {}", req.method, req.path),
                elapsed.as_nanos() as u64,
            );
        }
        if ctype == "application/json" {
            body = attach_trace_id(body, trace);
        }
        if writer
            .write_all(http_response(status, ctype, &body).as_bytes())
            .and_then(|_| writer.flush())
            .is_err()
        {
            return;
        }
        let close_requested =
            req.header("connection").map_or(false, |v| v.eq_ignore_ascii_case("close"));
        if close_requested || !state.running.load(Ordering::SeqCst) {
            return;
        }
    }
}

const JSON: &str = "application/json";
/// Prometheus text exposition format 0.0.4.
const PROM: &str = "text/plain; version=0.0.4";

fn route(req: &HttpRequest, state: &Arc<ServerState>) -> (u16, &'static str, String) {
    if req.method == "GET" {
        if req.path == "/metrics" {
            return (200, PROM, metrics_text(state));
        }
        if let Some(id) = req.path.strip_prefix("/trace/") {
            let (status, body) = trace_json(id);
            return (status, JSON, body);
        }
    }
    let (status, body) = match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => (200, "{\"ok\":true}".to_string()),
        ("GET", "/models") => (200, models_json(state)),
        ("GET", "/datasets") => (200, datasets_json(state)),
        ("GET", "/stats") => (200, stats_json(state)),
        ("POST", "/predict") => predict(req, state),
        ("POST", "/fit") => fit(req, state),
        ("POST", "/select") => select_route(req, state),
        ("POST", "/shutdown") => shutdown(state),
        ("GET", _) | ("POST", _) => {
            (404, format!("{{\"error\":\"no route {}\"}}", json_escape(&req.path)))
        }
        (m, _) => (405, format!("{{\"error\":\"method {} not allowed\"}}", json_escape(m))),
    };
    (status, JSON, body)
}

/// `GET /trace/<id>` — one request's span timeline as chrome://tracing
/// JSON (load it at chrome://tracing or ui.perfetto.dev).
fn trace_json(id: &str) -> (u16, String) {
    let Some(trace) = crate::obs::parse_trace_id(id) else {
        return (400, format!("{{\"error\":\"bad trace id '{}'\"}}", json_escape(id)));
    };
    match crate::obs::sink().get(trace) {
        Some(spans) => (200, crate::obs::chrome_trace_json(&spans)),
        None => (
            404,
            "{\"error\":\"trace unknown: never recorded (tracing off?), not yet flushed, or evicted from the bounded sink\"}"
                .to_string(),
        ),
    }
}

/// Low-cardinality route label for the request-latency histogram.
fn route_label(method: &str, path: &str) -> &'static str {
    match (method, path) {
        ("GET", "/healthz") => "healthz",
        ("GET", "/models") => "models",
        ("GET", "/datasets") => "datasets",
        ("GET", "/stats") => "stats",
        ("GET", "/metrics") => "metrics",
        ("GET", p) if p.starts_with("/trace/") => "trace",
        ("POST", "/predict") => "predict",
        ("POST", "/fit") => "fit",
        ("POST", "/select") => "select",
        ("POST", "/shutdown") => "shutdown",
        _ => "other",
    }
}

/// Per-route request-latency histogram in the global registry. The
/// lookup is one short mutex acquisition per request; observing is
/// lock-free.
fn request_histogram(label: &'static str) -> crate::obs::Histogram {
    crate::obs::global().histogram(
        "calars_http_request_seconds",
        &format!("route=\"{label}\""),
        "Wall time handling HTTP requests, by route.",
        &crate::obs::latency_bounds(),
    )
}

/// Echo the request's trace id into a JSON **object** body (inserted
/// right after the opening `{`, so clients that slice the body from
/// its first `[` keep working); anything else passes through
/// untouched.
fn attach_trace_id(body: String, trace: u64) -> String {
    let Some(rest) = body.strip_prefix('{') else { return body };
    let sep = if rest.trim_start().starts_with('}') { "" } else { "," };
    format!("{{\"trace_id\":\"{}\"{sep}{rest}", crate::obs::format_trace_id(trace))
}

/// JSON error body from an [`Error`]'s full context chain.
fn err_json(e: &Error) -> String {
    format!("{{\"error\":\"{}\"}}", json_escape(&format!("{e:#}")))
}

/// HTTP status for a typed error: bad user input → 400, server-side
/// failures (panicked workers) → 500. The 422 arm is reserved for
/// `ErrorKind::RankDeficient` *hard* failures — fitters currently
/// report recoverable rank deficiency inside a 200 response as
/// `stop=rank_deficient` (see `/models`), so this arm only fires if a
/// future producer surfaces the kind as an error.
fn error_status(e: &Error) -> u16 {
    match e.kind() {
        ErrorKind::RankDeficient => 422,
        ErrorKind::Internal => 500,
        ErrorKind::InvalidSpec | ErrorKind::Other => 400,
    }
}

fn predict(req: &HttpRequest, state: &Arc<ServerState>) -> (u16, String) {
    let parsed = match PredictRequest::parse(&req.body) {
        Ok(p) => p,
        Err(e) => return (400, err_json(&e)),
    };
    let queries: Vec<Query> = parsed
        .rows
        .into_iter()
        .map(|x| Query { model: parsed.model, selector: parsed.selector, x })
        .collect();
    let results = {
        // Covers the batch accumulation window + the shared GEMV.
        let _span = crate::obs::span("predict_batch_wait");
        state.batcher.submit_wait(queries)
    };
    let mut preds = Vec::with_capacity(results.len());
    for r in results {
        match r {
            Ok(v) => preds.push(json_f64(v)),
            Err(e) => return (error_status(&e), err_json(&e)),
        }
    }
    (200, format!("{{\"model\":{},\"predictions\":[{}]}}", parsed.model, preds.join(",")))
}

fn fit(req: &HttpRequest, state: &Arc<ServerState>) -> (u16, String) {
    if protocol::is_batch_fit(&req.body) {
        return fit_batch(req, state);
    }
    let parsed = match FitRequest::parse(&req.body) {
        Ok(p) => p,
        Err(e) => return (400, err_json(&e)),
    };
    // Resolve + validate the estimator spec up front so malformed
    // requests answer 4xx immediately instead of failing (or, worse,
    // panicking) inside a worker thread.
    let spec = match parsed.to_spec() {
        Ok(s) => s,
        Err(e) => return (error_status(&e), err_json(&e)),
    };
    let job = state.queue.submit(FitJob {
        name: parsed.name,
        dataset: parsed.dataset,
        seed: parsed.seed,
        spec,
        // The worker binds the fit to this request's trace, so the
        // phase spans land in the same /trace/<id> timeline.
        trace: crate::obs::current_trace(),
    });
    let st = if req.query_flag("wait") {
        state.queue.wait(job, Duration::from_secs(600))
    } else {
        state.queue.state(job)
    };
    (200, job_json(job, st.as_ref()))
}

/// Bulk `POST /fit` (a body with `y` rows): fit every posted response
/// against the dataset's design matrix in one
/// [`crate::fit::FitSpec::fit_batch`] lockstep call, register all k
/// models in one registry transaction, and answer with the model ids
/// plus the batch's shared-work accounting. Runs synchronously on this
/// connection thread — exactly as blocking as `/fit?wait=1`.
fn fit_batch(req: &HttpRequest, state: &Arc<ServerState>) -> (u16, String) {
    let parsed = match BatchFitRequest::parse(&req.body) {
        Ok(p) => p,
        Err(e) => return (400, err_json(&e)),
    };
    let spec = match parsed.base.to_spec() {
        Ok(s) => s,
        Err(e) => return (error_status(&e), err_json(&e)),
    };
    let job = BatchFitJob {
        names: parsed.model_names(),
        dataset: parsed.base.dataset.clone(),
        seed: parsed.base.seed,
        spec,
        responses: parsed.responses,
    };
    match state.queue.run_batch(&job) {
        Ok(out) => {
            let models: Vec<String> = out.models.iter().map(u64::to_string).collect();
            let s = &out.shared;
            (
                200,
                format!(
                    "{{\"models\":[{}],\"count\":{},\"shared\":{{\"responses\":{},\
                     \"gram_panel_hits\":{},\"gram_panel_misses\":{},\"batched_passes\":{},\
                     \"sequential_passes\":{},\"passes_saved\":{}}},\"wall_secs\":{}}}",
                    models.join(","),
                    out.models.len(),
                    s.responses,
                    s.gram_panel_hits,
                    s.gram_panel_misses,
                    s.batched_passes,
                    s.sequential_passes,
                    s.passes_saved(),
                    json_f64(out.wall_secs)
                ),
            )
        }
        Err(e) => (error_status(&e), err_json(&e)),
    }
}

/// `POST /select` — choose a serving step on a stored model's path.
///
/// In-sample criteria (cp/aic/bic) rank the stored snapshot directly.
/// `criterion cv` rebuilds the training problem from the model's
/// metadata (dataset + canonical fit spec) and runs seeded k-fold CV
/// with the fold fits fanned out on the [`crate::par`] pool; each fold
/// binds to an entry in the dedicated CV [`super::GramCache`]
/// (`/stats` → `cv_cache`), so deeper refits of the family reuse the
/// fold Gram panels (the warm-refit analogue of the fit path's panel
/// reuse) without fold shards evicting real datasets from the main
/// cache. The chosen step is recorded in the model's selection
/// metadata — an identical repeat `/select` answers from the cached
/// token without refitting.
fn select_route(req: &HttpRequest, state: &Arc<ServerState>) -> (u16, String) {
    let parsed = match SelectRequest::parse(&req.body) {
        Ok(p) => p,
        Err(e) => return (error_status(&e), err_json(&e)),
    };
    let sel_spec = match parsed.to_spec() {
        Ok(s) => s,
        Err(e) => return (error_status(&e), err_json(&e)),
    };
    let Some(rec) = state.registry.get(parsed.model) else {
        return (404, format!("{{\"error\":\"unknown model {}\"}}", parsed.model));
    };
    let key = sel_spec.token_key();
    if parsed.criterion != Criterion::Cv {
        return match select::rank_steps(&rec.snapshot, rec.meta.rows, parsed.criterion) {
            Ok(selection) => {
                // The upsert runs inside the registry lock so two
                // concurrent /selects never lose each other's tokens.
                state.registry.record_selection(rec.id, &key, selection.best_step);
                (200, selection_json(rec.id, &key, selection.best_step, Some(&selection), false))
            }
            Err(e) => (error_status(&e), err_json(&e)),
        };
    }
    // CV: an identical earlier selection answers from the metadata.
    if let Some(step) = select::find_selection(&rec.meta.selection, &key) {
        return (200, selection_json(rec.id, &key, step, None, true));
    }
    match cv_select(state, &rec, &sel_spec) {
        Ok(selection) => {
            // Serve from the full-data path: clamp in case every fold
            // path ran deeper than the stored one.
            let step = selection.best_step.min(rec.snapshot.len().saturating_sub(1));
            state.registry.record_selection(rec.id, &key, step);
            (200, selection_json(rec.id, &key, step, Some(&selection), false))
        }
        Err(e) => (error_status(&e), err_json(&e)),
    }
}

/// Run cross-validated selection for a stored model, rebuilding its
/// training problem from the registry metadata and binding every fold
/// fit to a GramCache-registered panel store.
fn cv_select(
    state: &Arc<ServerState>,
    rec: &ModelRecord,
    sel: &SelectSpec,
) -> Result<Selection> {
    let spec = FitSpec::parse(&rec.meta.spec)
        .context("model has no usable fit spec (ad-hoc insert?); cv needs one")?;
    let gram = state.queue.gram_cache();
    let ds = match gram.lookup(&rec.meta.dataset, rec.meta.seed) {
        Some((ds, _)) => ds,
        None => {
            let ds = Arc::new(
                datasets::by_name(&rec.meta.dataset, rec.meta.seed).ok_or_else(|| {
                    crate::anyhow!("dataset '{}' is not loadable", rec.meta.dataset)
                })?,
            );
            gram.register(&rec.meta.dataset, rec.meta.seed, Arc::clone(&ds));
            ds
        }
    };
    let base = format!("{}@{}#{}", rec.meta.dataset, rec.meta.seed, sel.token_key());
    let folds = &state.cv_cache;
    select::cross_validate_with(&ds.a, &ds.b, &spec, sel, |ctx, fit| {
        // Per-fold entry in the dedicated CV cache: fold construction
        // is deterministic, so a later /select on a deeper family
        // refit re-registers identical contents and its fit hits the
        // cached fold Gram panels — without fold clones competing with
        // the real datasets in the main GramCache.
        let name = format!("{base}:{}", ctx.fold);
        let store = match folds.lookup(&name, rec.meta.seed) {
            Some((_, store)) => store,
            None => {
                let fold_ds = Arc::new(Dataset {
                    name: name.clone(),
                    a: ctx.a.clone(),
                    b: ctx.b.to_vec(),
                    true_support: None,
                    col_norms: ctx.norms.to_vec(),
                });
                folds.register(&name, rec.meta.seed, fold_ds)
            }
        };
        kern::cache::with_store(&store, || select::fit_fold_snapshot(ctx, fit))
    })
}

/// JSON body for a selection result. `scores` is omitted for answers
/// served from cached selection metadata.
fn selection_json(
    model: u64,
    key: &str,
    step: usize,
    selection: Option<&Selection>,
    cached: bool,
) -> String {
    let scores = selection
        .map(|sel| {
            sel.scores
                .iter()
                .map(|s| {
                    format!(
                        "{{\"step\":{},\"df\":{},\"score\":{}}}",
                        s.step,
                        s.df,
                        json_f64(s.score)
                    )
                })
                .collect::<Vec<_>>()
                .join(",")
        })
        .unwrap_or_default();
    format!(
        "{{\"model\":{model},\"key\":\"{}\",\"step\":{step},\"cached\":{cached},\"scores\":[{scores}]}}",
        json_escape(key)
    )
}

fn shutdown(state: &Arc<ServerState>) -> (u16, String) {
    if !state.allow_shutdown {
        return (405, "{\"error\":\"shutdown disabled (run with --oneshot)\"}".to_string());
    }
    state.running.store(false, Ordering::SeqCst);
    // Wake the accept loop so it observes the flag.
    let _ = TcpStream::connect(state.addr);
    (200, "{\"ok\":true,\"stopping\":true}".to_string())
}

fn job_json(job: u64, state: Option<&JobState>) -> String {
    match state {
        None => format!("{{\"job\":{job},\"state\":\"unknown\"}}"),
        Some(s @ JobState::Done { model, reused, wall_secs }) => format!(
            "{{\"job\":{job},\"state\":\"{}\",\"model\":{model},\"reused\":{reused},\"wall_secs\":{}}}",
            s.word(),
            json_f64(*wall_secs)
        ),
        Some(s @ JobState::Failed { error }) => {
            format!("{{\"job\":{job},\"state\":\"{}\",\"error\":\"{}\"}}", s.word(), json_escape(error))
        }
        Some(s) => format!("{{\"job\":{job},\"state\":\"{}\"}}", s.word()),
    }
}

fn models_json(state: &Arc<ServerState>) -> String {
    let items: Vec<String> = state
        .registry
        .list()
        .iter()
        .map(|r| {
            let (lambda_max, lambda_min) = r.snapshot.lambda_range();
            format!(
                "{{\"id\":{},\"version\":{},\"name\":\"{}\",\"algo\":\"{}\",\"dataset\":\"{}\",\"t\":{},\"b\":{},\"p\":{},\"seed\":{},\"rows\":{},\"stop\":\"{}\",\"spec\":\"{}\",\"selection\":\"{}\",\"n\":{},\"steps\":{},\"max_support\":{},\"lambda_max\":{},\"lambda_min\":{},\"created_unix\":{}}}",
                r.id,
                r.version,
                json_escape(&r.meta.display_name()),
                json_escape(&r.meta.algo),
                json_escape(&r.meta.dataset),
                r.meta.t,
                r.meta.b,
                r.meta.p,
                r.meta.seed,
                r.meta.rows,
                json_escape(&r.meta.stop),
                json_escape(&r.meta.spec),
                json_escape(&r.meta.selection),
                r.snapshot.n,
                r.snapshot.len(),
                r.snapshot.max_support(),
                json_f64(lambda_max),
                json_f64(lambda_min),
                r.created_unix
            )
        })
        .collect();
    format!("{{\"models\":[{}]}}", items.join(","))
}

fn datasets_json(state: &Arc<ServerState>) -> String {
    let items: Vec<String> = state
        .queue
        .gram_cache()
        .list()
        .iter()
        .map(|d| {
            format!(
                "{{\"name\":\"{}\",\"seed\":{},\"fingerprint\":\"{:016x}\",\"m\":{},\"n\":{},\
                  \"norms\":{{\"count\":{},\"min\":{},\"max\":{},\"mean\":{}}},\
                  \"panels\":{{\"hits\":{},\"misses\":{},\"evictions\":{},\"held\":{},\"bytes\":{}}}}}",
                json_escape(&d.name),
                d.seed,
                d.fingerprint,
                d.m,
                d.n,
                d.norms.count,
                json_f64(d.norms.min),
                json_f64(d.norms.max),
                json_f64(d.norms.mean),
                d.panels.hits,
                d.panels.misses,
                d.panels.evictions,
                d.panels.panels,
                d.panels.bytes
            )
        })
        .collect();
    format!("{{\"datasets\":[{}]}}", items.join(","))
}

/// One gram-cache counter object (shared by the `gram_cache` and
/// `cv_cache` sections of `/stats`).
fn gram_stats_json(g: &super::GramCacheStats) -> String {
    format!(
        "{{\"datasets\":{},\"dataset_bytes\":{},\"dataset_hits\":{},\"dataset_misses\":{},\"invalidations\":{},\"evictions\":{},\"panel_hits\":{},\"panel_misses\":{},\"panel_evictions\":{},\"panels\":{},\"panel_bytes\":{}}}",
        g.datasets,
        g.dataset_bytes,
        g.dataset_hits,
        g.dataset_misses,
        g.invalidations,
        g.evictions,
        g.panel_hits,
        g.panel_misses,
        g.panel_evictions,
        g.panels,
        g.panel_bytes
    )
}

/// One scrape of every serving-layer counter group, gathered
/// back-to-back **before** any formatting starts. `/stats` and
/// `/metrics` both render from this. The old `/stats` read each
/// subsystem's lock lazily at format time, so a response could pair a
/// completed job count taken milliseconds after the submitted count it
/// is compared against (torn scrape); collecting first closes that
/// window and guarantees the two endpoints agree within one request.
struct StatsSnapshot {
    uptime_secs: f64,
    http_requests: u64,
    /// Kernel ISA backend the server process dispatches to (pinned at
    /// startup; see `crate::kern::simd`).
    isa: &'static str,
    engine: super::EngineStats,
    batcher: BatcherStats,
    queue: super::QueueStats,
    registry: RegistryStats,
    gram: super::GramCacheStats,
    cv: super::GramCacheStats,
    trace: crate::obs::SinkStats,
}

impl StatsSnapshot {
    fn collect(state: &ServerState) -> Self {
        StatsSnapshot {
            uptime_secs: state.started.elapsed().as_secs_f64(),
            http_requests: state.requests.load(Ordering::Relaxed),
            isa: crate::kern::simd::current().name(),
            engine: state.engine.stats(),
            batcher: state.batcher.stats(),
            queue: state.queue.stats(),
            registry: state.registry.stats(),
            gram: state.queue.gram_cache().stats(),
            cv: state.cv_cache.stats(),
            trace: crate::obs::sink().stats(),
        }
    }
}

fn stats_json(state: &Arc<ServerState>) -> String {
    let s = StatsSnapshot::collect(state);
    let (e, b, q, r) = (&s.engine, &s.batcher, &s.queue, &s.registry);
    format!(
        "{{\"uptime_secs\":{},\"http_requests\":{},\"isa\":\"{}\",\
          \"engine\":{{\"queries\":{},\"batches\":{},\"batched_rows\":{},\"max_batch_rows\":{},\"cache_hits\":{},\"cache_misses\":{},\"errors\":{}}},\
          \"batcher\":{{\"lock_recoveries\":{},\"engine_panics\":{}}},\
          \"queue\":{{\"submitted\":{},\"completed\":{},\"failed\":{},\"in_flight\":{},\"lock_recoveries\":{}}},\
          \"registry\":{{\"models\":{},\"inserted\":{},\"evicted\":{},\"warm_reused\":{},\"approx_bytes\":{}}},\
          \"gram_cache\":{},\
          \"cv_cache\":{},\
          \"trace\":{{\"traces\":{},\"spans\":{},\"recorded\":{},\"evicted\":{},\"slow_entries\":{}}}}}",
        json_f64(s.uptime_secs),
        s.http_requests,
        s.isa,
        e.queries,
        e.batches,
        e.batched_rows,
        e.max_batch_rows,
        e.cache_hits,
        e.cache_misses,
        e.errors,
        b.lock_recoveries,
        b.engine_panics,
        q.submitted,
        q.completed,
        q.failed,
        q.in_flight,
        q.lock_recoveries,
        r.models,
        r.inserted,
        r.evicted,
        r.warm_reused,
        r.approx_bytes,
        gram_stats_json(&s.gram),
        gram_stats_json(&s.cv),
        s.trace.traces,
        s.trace.spans,
        s.trace.recorded,
        s.trace.evicted,
        s.trace.slow_entries
    )
}

/// `GET /metrics` — Prometheus 0.0.4 text exposition: the global
/// registry (request/queue-wait latency histograms) followed by
/// counter/gauge families derived from the same [`StatsSnapshot`]
/// `/stats` serves, so the two endpoints never disagree within one
/// scrape.
fn metrics_text(state: &Arc<ServerState>) -> String {
    let s = StatsSnapshot::collect(state);
    let mut out = crate::obs::global().render();
    let mut fam = |name: &str, kind: &str, help: &str, samples: &[(&str, u64)]| {
        out.push_str("# HELP ");
        out.push_str(name);
        out.push(' ');
        out.push_str(help);
        out.push_str("\n# TYPE ");
        out.push_str(name);
        out.push(' ');
        out.push_str(kind);
        out.push('\n');
        for (labels, v) in samples {
            if labels.is_empty() {
                out.push_str(&format!("{name} {v}\n"));
            } else {
                out.push_str(&format!("{name}{{{labels}}} {v}\n"));
            }
        }
    };
    fam("calars_http_requests_total", "counter", "HTTP requests accepted.", &[("", s.http_requests)]);
    let isa_label = format!("isa=\"{}\"", s.isa);
    fam(
        "calars_isa_info",
        "gauge",
        "Kernel ISA backend the server dispatches to (constant 1).",
        &[(isa_label.as_str(), 1)],
    );
    fam(
        "calars_engine_queries_total",
        "counter",
        "Prediction queries answered by the engine.",
        &[("", s.engine.queries)],
    );
    fam(
        "calars_engine_batches_total",
        "counter",
        "Prediction batches drained (one shared GEMV each).",
        &[("", s.engine.batches)],
    );
    fam(
        "calars_engine_batched_rows_total",
        "counter",
        "Prediction rows evaluated through batches.",
        &[("", s.engine.batched_rows)],
    );
    fam(
        "calars_engine_cache_total",
        "counter",
        "Coefficient-snapshot cache lookups, by outcome.",
        &[("outcome=\"hit\"", s.engine.cache_hits), ("outcome=\"miss\"", s.engine.cache_misses)],
    );
    fam(
        "calars_engine_errors_total",
        "counter",
        "Prediction queries that answered an error.",
        &[("", s.engine.errors)],
    );
    fam(
        "calars_batcher_lock_recoveries_total",
        "counter",
        "Poisoned-lock recoveries inside the batcher.",
        &[("", s.batcher.lock_recoveries)],
    );
    fam(
        "calars_batcher_engine_panics_total",
        "counter",
        "Prediction batches that panicked inside the engine.",
        &[("", s.batcher.engine_panics)],
    );
    fam(
        "calars_fit_jobs_total",
        "counter",
        "Fit jobs by terminal state (submitted counts enqueues).",
        &[
            ("state=\"submitted\"", s.queue.submitted),
            ("state=\"completed\"", s.queue.completed),
            ("state=\"failed\"", s.queue.failed),
        ],
    );
    fam(
        "calars_fit_jobs_in_flight",
        "gauge",
        "Fit jobs submitted but not yet terminal.",
        &[("", s.queue.in_flight)],
    );
    fam(
        "calars_registry_models",
        "gauge",
        "Models currently held by the registry.",
        &[("", s.registry.models as u64)],
    );
    fam(
        "calars_registry_inserted_total",
        "counter",
        "Models inserted into the registry.",
        &[("", s.registry.inserted)],
    );
    fam(
        "calars_registry_evicted_total",
        "counter",
        "Models evicted from the registry (LRU).",
        &[("", s.registry.evicted)],
    );
    fam(
        "calars_registry_warm_reused_total",
        "counter",
        "Fit jobs answered by an already-stored covering path.",
        &[("", s.registry.warm_reused)],
    );
    fam(
        "calars_gram_panel_lookups_total",
        "counter",
        "Gram panel-store lookups, by cache and outcome.",
        &[
            ("cache=\"fit\",outcome=\"hit\"", s.gram.panel_hits),
            ("cache=\"fit\",outcome=\"miss\"", s.gram.panel_misses),
            ("cache=\"cv\",outcome=\"hit\"", s.cv.panel_hits),
            ("cache=\"cv\",outcome=\"miss\"", s.cv.panel_misses),
        ],
    );
    fam(
        "calars_trace_spans_recorded_total",
        "counter",
        "Spans absorbed by the trace sink.",
        &[("", s.trace.recorded)],
    );
    fam(
        "calars_trace_spans_evicted_total",
        "counter",
        "Spans dropped by the bounded trace sink (per-trace cap or trace eviction).",
        &[("", s.trace.evicted)],
    );
    fam(
        "calars_traces_held",
        "gauge",
        "Traces currently resolvable at /trace/<id>.",
        &[("", s.trace.traces)],
    );
    fam(
        "calars_slow_requests_held",
        "gauge",
        "Entries in the ring-buffered slow-request log.",
        &[("", s.trace.slow_entries)],
    );
    out.push_str(&format!(
        "# HELP calars_uptime_seconds Server uptime.\n# TYPE calars_uptime_seconds gauge\ncalars_uptime_seconds {}\n",
        json_f64(s.uptime_secs)
    ));
    out
}

// ── the cross-request batcher ───────────────────────────────────────

struct Pending {
    query: Query,
    slot: usize,
    tx: mpsc::Sender<(usize, Result<f64>)>,
}

/// Batcher counters exposed through `/stats`.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatcherStats {
    /// Poisoned-lock recoveries (a thread panicked inside a batcher
    /// critical section; the server kept serving).
    pub lock_recoveries: u64,
    /// Prediction batches that panicked inside the engine; their
    /// queries were failed with a typed 500 instead of killing the
    /// drain thread.
    pub engine_panics: u64,
}

/// Funnels prediction rows from all connection threads into one
/// [`PredictionEngine::predict_batch`] call per drain.
///
/// **Poison hardening** (bugfix): every lock acquisition recovers from
/// a poisoned mutex (`PoisonError::into_inner`) and counts the
/// recovery, and a panic inside the engine is caught per batch — the
/// affected queries answer 500, the drain thread lives on. The old
/// `.lock().unwrap()` sites turned one panicking worker into an abort
/// in every subsequent connection thread.
pub struct Batcher {
    queue: Mutex<Vec<Pending>>,
    cv: Condvar,
    stopping: AtomicBool,
    window: Duration,
    worker: Mutex<Option<thread::JoinHandle<()>>>,
    lock_recoveries: AtomicU64,
    engine_panics: AtomicU64,
}

impl Batcher {
    /// Start the drain thread.
    pub fn start(engine: Arc<PredictionEngine>, window: Duration) -> Arc<Batcher> {
        let b = Arc::new(Batcher {
            queue: Mutex::new(Vec::new()),
            cv: Condvar::new(),
            stopping: AtomicBool::new(false),
            window,
            worker: Mutex::new(None),
            lock_recoveries: AtomicU64::new(0),
            engine_panics: AtomicU64::new(0),
        });
        let b2 = Arc::clone(&b);
        let handle = thread::Builder::new()
            .name("calars-serve-batch".to_string())
            .spawn(move || b2.run(engine))
            // audit: allow(PANIC-UNWRAP) -- startup-time spawn: runs before the server accepts traffic, and a host that cannot spawn threads cannot serve
            .expect("spawn batcher");
        *lock_recover(&b.worker, &b.lock_recoveries) = Some(handle);
        b
    }

    /// Counter snapshot for `/stats`.
    pub fn stats(&self) -> BatcherStats {
        BatcherStats {
            lock_recoveries: self.lock_recoveries.load(Ordering::Relaxed),
            engine_panics: self.engine_panics.load(Ordering::Relaxed),
        }
    }

    fn run(&self, engine: Arc<PredictionEngine>) {
        loop {
            {
                let mut g = lock_recover(&self.queue, &self.lock_recoveries);
                while g.is_empty() && !self.stopping.load(Ordering::SeqCst) {
                    g = wait_recover(&self.cv, g, &self.lock_recoveries);
                }
                if g.is_empty() && self.stopping.load(Ordering::SeqCst) {
                    return;
                }
            }
            // Accumulation window: let concurrent connections pile on.
            if !self.window.is_zero() {
                thread::sleep(self.window);
            }
            let batch: Vec<Pending> =
                std::mem::take(&mut *lock_recover(&self.queue, &self.lock_recoveries));
            if batch.is_empty() {
                continue;
            }
            let mut queries = Vec::with_capacity(batch.len());
            let mut replies = Vec::with_capacity(batch.len());
            for p in batch {
                queries.push(p.query);
                replies.push((p.tx, p.slot));
            }
            // A panic inside the engine fails this batch's queries with
            // a typed 500; it must not kill the drain thread (every
            // later /predict would then hang until its poll timeout).
            let results = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                engine.predict_batch(&queries)
            }));
            match results {
                Ok(results) => {
                    for ((tx, slot), r) in replies.into_iter().zip(results) {
                        let _ = tx.send((slot, r));
                    }
                }
                Err(_) => {
                    self.engine_panics.fetch_add(1, Ordering::Relaxed);
                    for (tx, slot) in replies {
                        let _ = tx.send((
                            slot,
                            Err(Error::internal("prediction engine panicked; request failed")),
                        ));
                    }
                }
            }
        }
    }

    /// Enqueue queries and block until all are answered (order
    /// preserved).
    pub fn submit_wait(&self, queries: Vec<Query>) -> Vec<Result<f64>> {
        let n = queries.len();
        if n == 0 {
            return Vec::new();
        }
        if self.stopping.load(Ordering::SeqCst) {
            return queries.iter().map(|_| Err(crate::anyhow!("batcher shut down"))).collect();
        }
        let (tx, rx) = mpsc::channel();
        {
            let mut g = lock_recover(&self.queue, &self.lock_recoveries);
            for (slot, query) in queries.into_iter().enumerate() {
                g.push(Pending { query, slot, tx: tx.clone() });
            }
        }
        self.cv.notify_one();
        drop(tx);
        let mut out: Vec<Option<Result<f64>>> = (0..n).map(|_| None).collect();
        let mut got = 0usize;
        while got < n {
            // recv_timeout (not recv): a sender clone lives inside the
            // shared queue until the drain thread takes it, so a plain
            // recv could block forever if the batcher stops after our
            // enqueue. The poll bounds that race.
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok((slot, r)) => {
                    if let Some(cell) = out.get_mut(slot) {
                        if cell.is_none() {
                            got += 1;
                        }
                        *cell = Some(r);
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if self.stopping.load(Ordering::SeqCst) {
                        break;
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        out.into_iter()
            .map(|o| o.unwrap_or_else(|| Err(crate::anyhow!("batcher shut down"))))
            .collect()
    }

    /// Stop the drain thread; pending queries get errors (idempotent).
    pub fn stop(&self) {
        self.stopping.store(true, Ordering::SeqCst);
        self.cv.notify_all();
        if let Some(h) = lock_recover(&self.worker, &self.lock_recoveries).take() {
            let _ = h.join();
        }
        // Fail anything that slipped in after the drain thread exited:
        // dropping the pending entries drops their reply senders.
        let leftover = std::mem::take(&mut *lock_recover(&self.queue, &self.lock_recoveries));
        drop(leftover);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lars::path::{PathSnapshot, PathStep};
    use crate::serve::engine::Selector;
    use crate::serve::store::ModelMeta;

    fn engine_with_model() -> (Arc<PredictionEngine>, u64) {
        let steps = vec![
            PathStep { lambda: 2.0, support: vec![], coefs: vec![], residual_norm: 1.0 },
            PathStep { lambda: 1.0, support: vec![0], coefs: vec![3.0], residual_norm: 0.5 },
        ];
        let reg = Arc::new(ModelRegistry::new(4));
        let id = reg.insert(ModelMeta::named("m"), PathSnapshot { n: 2, steps });
        (Arc::new(PredictionEngine::new(reg, 8)), id)
    }

    #[test]
    fn batcher_groups_concurrent_submissions() {
        let (engine, id) = engine_with_model();
        let b = Batcher::start(engine.clone(), Duration::from_millis(20));
        let mut joins = Vec::new();
        for i in 0..4 {
            let b = Arc::clone(&b);
            joins.push(thread::spawn(move || {
                b.submit_wait(vec![Query {
                    model: id,
                    selector: Selector::Step(1),
                    x: vec![i as f64, 1.0],
                }])
            }));
        }
        for (i, j) in joins.into_iter().enumerate() {
            let r = j.join().unwrap();
            assert_eq!(r.len(), 1);
            assert_eq!(r[0].as_ref().unwrap(), &(3.0 * i as f64));
        }
        let s = engine.stats();
        assert!(
            s.max_batch_rows >= 2,
            "the 20ms window should capture ≥ 2 concurrent rows, saw {}",
            s.max_batch_rows
        );
        b.stop();
    }

    #[test]
    fn poisoned_batcher_lock_recovers_instead_of_cascading() {
        // Regression: a thread panicking while holding the batcher
        // queue lock used to poison it, and every later connection
        // thread died at `.lock().unwrap()`. The batcher now recovers,
        // counts the recovery (surfaced via /stats), and keeps
        // answering predictions.
        let (engine, id) = engine_with_model();
        let b = Batcher::start(engine, Duration::from_micros(0));
        let b2 = Arc::clone(&b);
        let _ = thread::spawn(move || {
            let _guard = b2.queue.lock().unwrap();
            panic!("poison the batcher queue lock");
        })
        .join();
        // Pre-fix this panicked; now it serves.
        let r = b.submit_wait(vec![Query {
            model: id,
            selector: Selector::Step(1),
            x: vec![2.0, 0.0],
        }]);
        assert_eq!(r[0].as_ref().unwrap(), &6.0);
        assert!(b.stats().lock_recoveries >= 1, "{:?}", b.stats());
        b.stop();
    }

    #[test]
    fn attach_trace_id_prepends_into_json_objects() {
        assert_eq!(attach_trace_id("{}".into(), 0x2a), "{\"trace_id\":\"000000000000002a\"}");
        assert_eq!(
            attach_trace_id("{\"ok\":true}".into(), 1),
            "{\"trace_id\":\"0000000000000001\",\"ok\":true}"
        );
        // Non-object bodies pass through untouched.
        assert_eq!(attach_trace_id("plain".into(), 1), "plain");
    }

    #[test]
    fn route_labels_are_low_cardinality() {
        assert_eq!(route_label("GET", "/trace/00ff"), "trace");
        assert_eq!(route_label("POST", "/fit"), "fit");
        assert_eq!(route_label("GET", "/no-such-path"), "other");
        assert_eq!(route_label("PUT", "/fit"), "other");
    }

    #[test]
    fn batcher_stop_fails_pending_cleanly() {
        let (engine, id) = engine_with_model();
        let b = Batcher::start(engine, Duration::from_micros(0));
        let r = b.submit_wait(vec![Query { model: id, selector: Selector::Step(1), x: vec![2.0, 0.0] }]);
        assert_eq!(r[0].as_ref().unwrap(), &6.0);
        b.stop();
        let r = b.submit_wait(vec![Query { model: id, selector: Selector::Step(1), x: vec![1.0, 0.0] }]);
        assert!(r[0].is_err(), "after stop, submissions fail instead of hanging");
    }
}
