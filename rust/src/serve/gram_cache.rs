//! Per-dataset Gram/norm cache for the serving layer.
//!
//! Every `/fit` used to regenerate its dataset and recompute every
//! Gram panel from scratch — including warm-started refits of a model
//! family, whose selection prefix (and therefore whose panel keys)
//! repeat exactly. [`GramCache`] holds, per dataset **name**:
//!
//! * the loaded dataset itself (generation + column normalization are
//!   full passes over the data);
//! * its pre-normalization column norms (free by-product of the fused
//!   normalize pass, see `Matrix::normalize_columns_with_norms`);
//! * a [`crate::kern::cache::PanelStore`] of previously materialized
//!   Gram panels, which `Matrix::gram_block` consults while the fit
//!   runs under [`crate::kern::cache::with_store`].
//!
//! **Identity + invalidation.** The name is the dataset's identity; a
//! content *fingerprint* (FNV-1a over shape, nnz, and sampled value
//! bits of `A` and `b`) validates it. Registering a name whose
//! fingerprint differs from the cached entry — a dataset re-uploaded
//! with different contents — invalidates the old entry (norms and all
//! panels) instead of serving stale values. The cache holds at most
//! `max_datasets` entries, evicting least-recently-used.
//!
//! Counters surface through `/stats` as `gram_cache` (hit/miss at both
//! the dataset and the panel level, evictions, invalidations).

use crate::data::datasets::Dataset;
use crate::kern::cache::{LruQueue, PanelCounters, PanelStore};
use crate::linalg::Matrix;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Counter snapshot for `/stats`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GramCacheStats {
    /// Dataset entries currently cached.
    pub datasets: usize,
    /// Fits that found their dataset (and panel store) cached.
    pub dataset_hits: u64,
    /// Fits that had to load + register their dataset.
    pub dataset_misses: u64,
    /// Entries dropped because a name re-registered with different
    /// contents.
    pub invalidations: u64,
    /// Entries dropped by the LRU bounds (entry count or dataset
    /// payload bytes).
    pub evictions: u64,
    /// Approximate payload bytes of the cached datasets themselves.
    pub dataset_bytes: usize,
    /// Panel-level counters aggregated over live and retired entries.
    pub panel_hits: u64,
    pub panel_misses: u64,
    pub panel_evictions: u64,
    /// Panels and payload bytes currently held across live entries.
    pub panels: usize,
    pub panel_bytes: usize,
}

/// Summary of a dataset's stored pre-normalization column norms —
/// the per-column scale the fitted models assume was divided out. A
/// client predicting from *raw* (unnormalized) features needs these to
/// rescale inputs; `/datasets` serves the summary so operators can see
/// the training scale without shipping the full vector.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NormSummary {
    pub count: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
}

impl NormSummary {
    fn from_norms(norms: Option<&Vec<f64>>) -> NormSummary {
        let Some(norms) = norms else { return NormSummary::default() };
        if norms.is_empty() {
            return NormSummary::default();
        }
        let (mut lo, mut hi, mut sum) = (f64::INFINITY, f64::NEG_INFINITY, 0.0);
        for &v in norms.iter() {
            lo = lo.min(v);
            hi = hi.max(v);
            sum += v;
        }
        NormSummary { count: norms.len(), min: lo, max: hi, mean: sum / norms.len() as f64 }
    }
}

/// One row of the `/datasets` listing (see [`GramCache::list`]).
#[derive(Clone, Debug)]
pub struct DatasetInfo {
    pub name: String,
    pub seed: u64,
    pub fingerprint: u64,
    pub m: usize,
    pub n: usize,
    pub norms: NormSummary,
    pub panels: crate::kern::cache::PanelCounters,
}

struct Entry {
    seed: u64,
    fingerprint: u64,
    dataset: Arc<Dataset>,
    /// Approximate payload bytes of `dataset` (counted against
    /// `max_dataset_bytes`).
    bytes: usize,
    store: Arc<PanelStore>,
}

struct Inner {
    entries: HashMap<String, Entry>,
    lru: LruQueue<String>,
    /// Sum of `Entry::bytes` over live entries.
    dataset_bytes: usize,
    dataset_hits: u64,
    dataset_misses: u64,
    invalidations: u64,
    evictions: u64,
    /// Panel counters folded in from dropped entries.
    retired: PanelCounters,
}

/// Thread-safe dataset-keyed cache of datasets, norms, and Gram panel
/// stores. Triple-bounded: entry count (`max_datasets`), panel
/// payload per entry (`max_panel_bytes`), and the cached datasets'
/// own payload across entries (`max_dataset_bytes` — wide sparse
/// datasets run to tens of MB each, so a count bound alone could pin
/// hundreds of MB of RSS).
pub struct GramCache {
    max_datasets: usize,
    max_panel_bytes: usize,
    max_dataset_bytes: usize,
    inner: Mutex<Inner>,
}

/// Default bound on cached dataset payload (256 MiB).
const DEFAULT_MAX_DATASET_BYTES: usize = 256 << 20;

impl Default for GramCache {
    /// Serving defaults: 8 datasets, 32 MiB of panels each, 256 MiB of
    /// dataset payload overall.
    fn default() -> Self {
        GramCache::new(8, 32 << 20)
    }
}

impl GramCache {
    /// Cache holding at most `max_datasets` entries (≥ 1), each with at
    /// most `max_panel_bytes` of Gram panel payload, and at most
    /// [`DEFAULT_MAX_DATASET_BYTES`](GramCache::dataset_byte_bound) of
    /// dataset payload overall.
    pub fn new(max_datasets: usize, max_panel_bytes: usize) -> Self {
        GramCache {
            max_datasets: max_datasets.max(1),
            max_panel_bytes,
            max_dataset_bytes: DEFAULT_MAX_DATASET_BYTES,
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                lru: LruQueue::new(),
                dataset_bytes: 0,
                dataset_hits: 0,
                dataset_misses: 0,
                invalidations: 0,
                evictions: 0,
                retired: PanelCounters::default(),
            }),
        }
    }

    /// Override the dataset-payload byte bound (tests, memory-tight
    /// deployments). Eviction always keeps the most recent entry so
    /// the fit that just registered it can run.
    pub fn dataset_byte_bound(mut self, max_dataset_bytes: usize) -> Self {
        self.max_dataset_bytes = max_dataset_bytes;
        self
    }

    /// Cached dataset + panel store for `(name, seed)`, marking the
    /// entry most-recently-used. A cached entry under the same name
    /// but a different seed does **not** match (different contents);
    /// the subsequent [`Self::register`] will invalidate it.
    pub fn lookup(&self, name: &str, seed: u64) -> Option<(Arc<Dataset>, Arc<PanelStore>)> {
        let mut guard = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let g = &mut *guard;
        match g.entries.get(name) {
            Some(e) if e.seed == seed => {
                let hit = (Arc::clone(&e.dataset), Arc::clone(&e.store));
                g.lru.touch_or_push(name.to_string());
                g.dataset_hits += 1;
                Some(hit)
            }
            _ => {
                g.dataset_misses += 1;
                None
            }
        }
    }

    /// Register a freshly loaded dataset under `name`, returning its
    /// panel store (pre-seeded with the dataset's column norms). An
    /// existing entry whose fingerprint differs — same name,
    /// different contents — is invalidated; registering identical
    /// contents again just refreshes the entry.
    pub fn register(&self, name: &str, seed: u64, dataset: Arc<Dataset>) -> Arc<PanelStore> {
        let fingerprint = fingerprint_dataset(&dataset);
        let mut guard = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let g = &mut *guard;
        if let Some(e) = g.entries.get(name) {
            if e.fingerprint == fingerprint {
                // Identical contents (e.g. two workers raced the same
                // miss): keep the existing store and its panels.
                let store = Arc::clone(&e.store);
                g.lru.touch_or_push(name.to_string());
                return store;
            }
        }
        // Same name, different contents: invalidate the stale entry
        // (remove is a no-op when the name was never registered).
        if let Some(old) = g.entries.remove(name) {
            g.lru.remove_by(|k| k == name);
            g.dataset_bytes -= old.bytes;
            g.invalidations += 1;
            fold_retired(&mut g.retired, &old.store.counters());
        }
        let shape = (dataset.a.nrows(), dataset.a.ncols());
        let bytes = approx_dataset_bytes(&dataset);
        let store = Arc::new(PanelStore::new(shape, self.max_panel_bytes));
        store.set_norms(Arc::new(dataset.col_norms.clone()));
        g.entries.insert(
            name.to_string(),
            Entry { seed, fingerprint, dataset, bytes, store: Arc::clone(&store) },
        );
        g.dataset_bytes += bytes;
        g.lru.touch_or_push(name.to_string());
        // Evict under either bound, but never the entry just
        // registered (the caller's fit needs it).
        while g.entries.len() > 1
            && (g.entries.len() > self.max_datasets
                || g.dataset_bytes > self.max_dataset_bytes)
        {
            let Some(victim) = g.lru.pop_lru() else { break };
            if let Some(old) = g.entries.remove(&victim) {
                g.dataset_bytes -= old.bytes;
                g.evictions += 1;
                fold_retired(&mut g.retired, &old.store.counters());
            }
        }
        store
    }

    /// Live dataset entries for the `/datasets` listing, sorted by
    /// name: identity (name/seed/fingerprint/shape), a summary of the
    /// stored pre-normalization column norms (the scale a client must
    /// divide raw features by to match the unit-norm training data),
    /// and the entry's panel counters.
    pub fn list(&self) -> Vec<DatasetInfo> {
        let g = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let mut out: Vec<DatasetInfo> = g
            .entries
            .iter()
            .map(|(name, e)| DatasetInfo {
                name: name.clone(),
                seed: e.seed,
                fingerprint: e.fingerprint,
                m: e.dataset.a.nrows(),
                n: e.dataset.a.ncols(),
                norms: NormSummary::from_norms(e.store.norms().as_deref()),
                panels: e.store.counters(),
            })
            .collect();
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }

    /// Counter snapshot (live entries + retired accumulators).
    pub fn stats(&self) -> GramCacheStats {
        // audit: allow(LOCK-ORDER) -- the reported cycle is a name-resolution artifact (std collection get/insert under a held guard resolve to other caches' methods); the only real nesting is GramCache.inner -> PanelStore.inner at registration, and nothing acquires those locks in the reverse order
        let g = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let mut s = GramCacheStats {
            datasets: g.entries.len(),
            dataset_bytes: g.dataset_bytes,
            dataset_hits: g.dataset_hits,
            dataset_misses: g.dataset_misses,
            invalidations: g.invalidations,
            evictions: g.evictions,
            panel_hits: g.retired.hits,
            panel_misses: g.retired.misses,
            panel_evictions: g.retired.evictions,
            panels: 0,
            panel_bytes: 0,
        };
        for e in g.entries.values() {
            let c = e.store.counters();
            s.panel_hits += c.hits;
            s.panel_misses += c.misses;
            s.panel_evictions += c.evictions;
            s.panels += c.panels;
            s.panel_bytes += c.bytes;
        }
        s
    }
}

/// Approximate in-memory payload of a dataset: matrix values (+ row
/// indices and column pointers for CSC), response, and norms.
fn approx_dataset_bytes(ds: &Dataset) -> usize {
    let matrix = match &ds.a {
        Matrix::Dense(d) => d.nrows() * d.ncols() * 8,
        Matrix::Sparse(s) => s.nnz() * 12 + (s.ncols() + 1) * 8,
    };
    matrix + ds.b.len() * 8 + ds.col_norms.len() * 8
}

fn fold_retired(retired: &mut PanelCounters, c: &PanelCounters) {
    retired.hits += c.hits;
    retired.misses += c.misses;
    retired.evictions += c.evictions;
}

// ── content fingerprint ─────────────────────────────────────────────

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv_u64(h: u64, v: u64) -> u64 {
    let mut h = h;
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Stride that samples at most ~1024 elements of a length-`len` slice.
#[inline]
fn sample_stride(len: usize) -> usize {
    (len / 1024).max(1)
}

/// FNV-1a content fingerprint of a matrix: shape, nnz, and a strided
/// sample of value bit patterns (plus row indices for CSC). Cheap
/// (≤ ~2k hashed words) yet sensitive to any re-upload that changes
/// shape, sparsity structure, or sampled values.
pub fn fingerprint_matrix(a: &Matrix) -> u64 {
    let mut h = FNV_OFFSET;
    h = fnv_u64(h, a.nrows() as u64);
    h = fnv_u64(h, a.ncols() as u64);
    h = fnv_u64(h, a.nnz() as u64);
    match a {
        Matrix::Dense(d) => {
            let data = d.data();
            let stride = sample_stride(data.len());
            let mut i = 0;
            while i < data.len() {
                h = fnv_u64(h, data[i].to_bits());
                i += stride;
            }
        }
        Matrix::Sparse(s) => {
            let ncols = s.ncols();
            let col_stride = sample_stride(ncols);
            let mut j = 0;
            while j < ncols {
                let (rows, vals) = s.col(j);
                h = fnv_u64(h, rows.len() as u64);
                if let (Some(&r0), Some(&v0)) = (rows.first(), vals.first()) {
                    h = fnv_u64(h, r0 as u64);
                    h = fnv_u64(h, v0.to_bits());
                }
                if let (Some(&rl), Some(&vl)) = (rows.last(), vals.last()) {
                    h = fnv_u64(h, rl as u64);
                    h = fnv_u64(h, vl.to_bits());
                }
                j += col_stride;
            }
        }
    }
    h
}

/// Fingerprint of a full dataset (`A` plus a strided sample of `b`).
pub fn fingerprint_dataset(ds: &Dataset) -> u64 {
    let mut h = fingerprint_matrix(&ds.a);
    h = fnv_u64(h, ds.b.len() as u64);
    let stride = sample_stride(ds.b.len());
    let mut i = 0;
    while i < ds.b.len() {
        h = fnv_u64(h, ds.b[i].to_bits());
        i += stride;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;

    fn ds(seed: u64) -> Arc<Dataset> {
        Arc::new(datasets::tiny(seed))
    }

    #[test]
    fn fingerprint_is_deterministic_and_content_sensitive() {
        let a = ds(1);
        let b = ds(1);
        let c = ds(2);
        assert_eq!(fingerprint_dataset(&a), fingerprint_dataset(&b));
        assert_ne!(fingerprint_dataset(&a), fingerprint_dataset(&c));
        let dense = Arc::new(datasets::tiny_dense(3));
        assert_ne!(fingerprint_dataset(&a), fingerprint_dataset(&dense));
    }

    #[test]
    fn lookup_register_hit_miss_counters() {
        let cache = GramCache::new(4, 1 << 20);
        assert!(cache.lookup("tiny", 1).is_none());
        let d = ds(1);
        let store = cache.register("tiny", 1, d.clone());
        store.insert(&[0], &[1], Arc::new(vec![0.5]));
        let (back, store2) = cache.lookup("tiny", 1).expect("registered");
        assert!(Arc::ptr_eq(&back, &d));
        assert!(store2.lookup(&[0], &[1]).is_some(), "panels survive across lookups");
        let s = cache.stats();
        assert_eq!((s.dataset_hits, s.dataset_misses, s.datasets), (1, 1, 1));
        assert_eq!(s.panel_hits, 1);
        assert!(s.panels == 1 && s.panel_bytes == 8);
        // Norms were seeded from the dataset at registration.
        assert_eq!(store2.norms().unwrap().len(), d.a.ncols());
    }

    #[test]
    fn reupload_with_different_contents_invalidates() {
        let cache = GramCache::new(4, 1 << 20);
        let store = cache.register("tiny", 1, ds(1));
        store.insert(&[0], &[0], Arc::new(vec![1.0]));
        // Same name, different contents (different seed) → stale entry
        // must be dropped, not served.
        assert!(cache.lookup("tiny", 2).is_none());
        let store2 = cache.register("tiny", 2, ds(2));
        assert!(store2.lookup(&[0], &[0]).is_none(), "panels of the old contents are gone");
        let s = cache.stats();
        assert_eq!(s.invalidations, 1);
        assert_eq!(s.datasets, 1);
        // Re-registering identical contents keeps the live store.
        store2.insert(&[1], &[1], Arc::new(vec![2.0]));
        let store3 = cache.register("tiny", 2, ds(2));
        assert!(store3.lookup(&[1], &[1]).is_some());
        assert_eq!(cache.stats().invalidations, 1);
    }

    #[test]
    fn list_reports_identity_and_norm_summary() {
        let cache = GramCache::new(4, 1 << 20);
        let d = ds(1);
        cache.register("tiny", 1, d.clone());
        let rows = cache.list();
        assert_eq!(rows.len(), 1);
        let row = &rows[0];
        assert_eq!(row.name, "tiny");
        assert_eq!((row.m, row.n), (d.a.nrows(), d.a.ncols()));
        assert_eq!(row.norms.count, d.col_norms.len());
        let mean = d.col_norms.iter().sum::<f64>() / d.col_norms.len() as f64;
        assert!((row.norms.mean - mean).abs() < 1e-12);
        assert!(row.norms.min <= row.norms.mean && row.norms.mean <= row.norms.max);
        assert_eq!(row.fingerprint, fingerprint_dataset(&d));
    }

    #[test]
    fn lru_bound_evicts_oldest_dataset() {
        let cache = GramCache::new(2, 1 << 20);
        cache.register("a", 1, ds(1));
        cache.register("b", 1, ds(2));
        assert!(cache.lookup("a", 1).is_some()); // a more recent than b
        cache.register("c", 1, ds(3));
        assert!(cache.lookup("b", 1).is_none(), "LRU dataset evicted");
        assert!(cache.lookup("a", 1).is_some());
        assert!(cache.lookup("c", 1).is_some());
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.datasets, 2);
        assert!(s.dataset_bytes > 0);
    }

    #[test]
    fn dataset_byte_bound_evicts_but_keeps_newest() {
        // A bound smaller than one dataset: every register evicts the
        // previous entry but never the one just registered.
        let cache = GramCache::new(8, 1 << 20).dataset_byte_bound(1);
        cache.register("a", 1, ds(1));
        assert_eq!(cache.stats().datasets, 1, "newest survives an over-budget bound");
        cache.register("b", 1, ds(2));
        let s = cache.stats();
        assert_eq!(s.datasets, 1, "byte bound evicted the older dataset");
        assert!(cache.lookup("a", 1).is_none());
        assert!(cache.lookup("b", 1).is_some());
        assert_eq!(s.evictions, 1);
    }
}
