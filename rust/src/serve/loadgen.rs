//! Closed-loop load generation against a running `calars serve`
//! instance, plus the minimal HTTP client it (and the tests) use.
//!
//! Each of `concurrency` client threads drives its own keep-alive
//! connection: build a predict request with `rows` random feature
//! vectors, send, wait for the response, repeat — closed loop, so
//! measured latency includes queueing inside the server's batcher.
//! The report aggregates throughput and latency percentiles via
//! [`crate::metrics::LatencyStats`].

use super::engine::Selector;
use super::protocol::{self, FitRequest, PredictRequest, SelectRequest};
use crate::error::{bail, Context, Result};
use crate::metrics::LatencyStats;
use crate::rng::Pcg64;
use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::time::Instant;

/// Minimal keep-alive HTTP client for the serve protocol.
pub struct ServeClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl ServeClient {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(stream.try_clone().context("clone stream")?);
        Ok(ServeClient { writer: stream, reader })
    }

    /// Issue one request; returns `(status, body)`.
    pub fn request(&mut self, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: calars\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n",
            body.len()
        );
        self.writer.write_all(head.as_bytes())?;
        self.writer.write_all(body.as_bytes())?;
        self.writer.flush()?;
        protocol::read_http_response(&mut self.reader)
    }

    /// Submit a fit and (optionally) wait for it; returns the model id
    /// on success.
    pub fn fit(&mut self, req: &FitRequest, wait: bool) -> Result<u64> {
        let path = if wait { "/fit?wait=1" } else { "/fit" };
        let (status, body) = self.request("POST", path, &req.encode())?;
        if status != 200 {
            bail!("fit failed with HTTP {status}: {body}");
        }
        match protocol::json_find_str(&body, "state") {
            Some("done") => protocol::json_find_u64(&body, "model")
                .context("fit response missing model id"),
            Some(other) => bail!("fit ended in state '{other}': {body}"),
            None => bail!("unparseable fit response: {body}"),
        }
    }

    pub fn predict(&mut self, req: &PredictRequest) -> Result<(u16, String)> {
        self.request("POST", "/predict", &req.encode())
    }

    /// Run model selection on a stored path; returns the chosen step
    /// on success.
    pub fn select(&mut self, req: &SelectRequest) -> Result<u64> {
        let (status, body) = self.request("POST", "/select", &req.encode())?;
        if status != 200 {
            bail!("select failed with HTTP {status}: {body}");
        }
        protocol::json_find_u64(&body, "step").context("select response missing step")
    }

    /// Feature dimension `n` of a registered model (via `GET /models`).
    pub fn model_dim(&mut self, model: u64) -> Result<usize> {
        let (status, body) = self.request("GET", "/models", "")?;
        if status != 200 {
            bail!("GET /models failed with HTTP {status}");
        }
        let marker = format!("\"id\":{model},");
        let at = body
            .find(&marker)
            .with_context(|| format!("model {model} not in registry listing"))?;
        protocol::json_find_u64(&body[at..], "n")
            .map(|n| n as usize)
            .context("model entry missing dimension")
    }

    /// Request a graceful server stop (requires `--oneshot` server side).
    pub fn shutdown(&mut self) -> Result<()> {
        let (status, body) = self.request("POST", "/shutdown", "")?;
        if status != 200 {
            bail!("shutdown refused with HTTP {status}: {body}");
        }
        Ok(())
    }
}

/// Load-run shape.
#[derive(Clone, Debug)]
pub struct LoadOptions {
    /// Total predict requests across all workers.
    pub requests: usize,
    /// Concurrent client connections.
    pub concurrency: usize,
    /// Query rows per request.
    pub rows: usize,
    /// Target model id.
    pub model: u64,
    /// Path position queried.
    pub selector: Selector,
    /// Feature dimension of the target model.
    pub dim: usize,
    pub seed: u64,
}

impl Default for LoadOptions {
    fn default() -> Self {
        LoadOptions {
            requests: 1000,
            concurrency: 4,
            rows: 4,
            model: 1,
            selector: Selector::Step(4),
            dim: 1,
            seed: 42,
        }
    }
}

/// Aggregated result of a load run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub requests: usize,
    pub rows: usize,
    pub errors: usize,
    pub wall_secs: f64,
    /// Completed requests per second.
    pub request_throughput: f64,
    /// Query rows per second.
    pub row_throughput: f64,
    /// Per-request latency, seconds.
    pub latency: LatencyStats,
}

impl LoadReport {
    /// Human-readable multi-line summary.
    pub fn render(&self) -> String {
        use crate::metrics::fmt_secs;
        format!(
            "requests {} ({} rows, {} errors) in {}\n\
             throughput {:.0} req/s | {:.0} rows/s\n\
             latency p50 {} | p90 {} | p99 {} | max {}",
            self.requests,
            self.rows,
            self.errors,
            fmt_secs(self.wall_secs),
            self.request_throughput,
            self.row_throughput,
            fmt_secs(self.latency.p50),
            fmt_secs(self.latency.p90),
            fmt_secs(self.latency.p99),
            fmt_secs(self.latency.max),
        )
    }
}

/// Run a closed-loop load test; returns the aggregated report.
pub fn run_load(addr: &str, opts: &LoadOptions) -> Result<LoadReport> {
    if opts.requests == 0 || opts.concurrency == 0 || opts.rows == 0 {
        bail!("requests, concurrency and rows must all be ≥ 1");
    }
    let workers = opts.concurrency.min(opts.requests);
    let base = opts.requests / workers;
    let extra = opts.requests % workers;
    let t0 = Instant::now();
    let results: Vec<Result<(Vec<f64>, usize)>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let quota = base + usize::from(w < extra);
            let opts = opts.clone();
            let addr = addr.to_string();
            handles.push(scope.spawn(move || load_worker(&addr, &opts, w as u64, quota)));
        }
        handles.into_iter().map(|h| h.join().expect("load worker panicked")).collect()
    });
    let wall_secs = t0.elapsed().as_secs_f64();

    let mut latencies = Vec::with_capacity(opts.requests);
    let mut errors = 0usize;
    for r in results {
        let (lats, errs) = r?;
        latencies.extend(lats);
        errors += errs;
    }
    let completed = latencies.len();
    let latency = LatencyStats::from_samples(latencies);
    Ok(LoadReport {
        requests: completed,
        rows: completed * opts.rows,
        errors,
        wall_secs,
        request_throughput: completed as f64 / wall_secs.max(1e-12),
        row_throughput: (completed * opts.rows) as f64 / wall_secs.max(1e-12),
        latency,
    })
}

fn load_worker(
    addr: &str,
    opts: &LoadOptions,
    widx: u64,
    quota: usize,
) -> Result<(Vec<f64>, usize)> {
    let mut client = ServeClient::connect(addr)?;
    let mut rng = Pcg64::new(opts.seed ^ widx.wrapping_mul(0x9E3779B97F4A7C15));
    let mut latencies = Vec::with_capacity(quota);
    let mut errors = 0usize;
    for _ in 0..quota {
        let rows: Vec<Vec<f64>> =
            (0..opts.rows).map(|_| (0..opts.dim).map(|_| rng.normal()).collect()).collect();
        let req = PredictRequest { model: opts.model, selector: opts.selector, rows };
        let t = Instant::now();
        match client.predict(&req) {
            Ok((200, _)) => latencies.push(t.elapsed().as_secs_f64()),
            Ok((_status, _body)) => errors += 1,
            Err(_) => {
                errors += 1;
                // One reconnect attempt keeps a dropped keep-alive
                // connection from failing the rest of the quota.
                client = ServeClient::connect(addr)?;
            }
        }
    }
    Ok((latencies, errors))
}
