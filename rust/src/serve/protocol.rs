//! Wire formats for the serving front end — all hand-rolled (the
//! offline crate set has no HTTP or JSON dependency, same no-deps
//! spirit as [`crate::config::Args`]).
//!
//! Three layers, each testable without sockets:
//!
//! * a **line protocol** for request bodies (`key value` lines,
//!   `x v1 v2 …` query rows) — [`PredictRequest`]/[`FitRequest`]
//!   encode/parse round-trip exactly (f64s print via Rust's shortest
//!   round-trippable `Display`);
//! * minimal **HTTP/1.1 framing**: request/response reader and writer
//!   supporting `Content-Length` bodies and keep-alive;
//! * a tiny **JSON emitter** (plus a scanner for the few fields our
//!   own client needs back).

use super::engine::Selector;
use crate::error::{bail, Context, Result};
use crate::select::{Criterion, SelectSpec};
use std::io::{BufRead, Read};

// ── line protocol: /predict ─────────────────────────────────────────

/// Body of `POST /predict`.
///
/// ```text
/// model 3
/// step 5          # or: lambda 0.25, or: auto cp|aic|bic
/// x 0.1 0.2 0.3
/// x 1 0 2
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct PredictRequest {
    pub model: u64,
    pub selector: Selector,
    /// One feature vector per query row.
    pub rows: Vec<Vec<f64>>,
}

impl PredictRequest {
    pub fn encode(&self) -> String {
        let mut s = format!("model {}\n", self.model);
        match self.selector {
            Selector::Step(k) => s.push_str(&format!("step {k}\n")),
            Selector::Lambda(l) => s.push_str(&format!("lambda {l}\n")),
            Selector::Auto(c) => s.push_str(&format!("auto {}\n", c.name())),
        }
        for row in &self.rows {
            s.push('x');
            for v in row {
                s.push(' ');
                s.push_str(&v.to_string());
            }
            s.push('\n');
        }
        s
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut model: Option<u64> = None;
        let mut selector: Option<Selector> = None;
        let mut rows: Vec<Vec<f64>> = Vec::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            let (key, rest) = line.split_once(' ').unwrap_or((line, ""));
            match key {
                "model" => {
                    model = Some(
                        rest.trim()
                            .parse()
                            .with_context(|| format!("line {}: bad model id", ln + 1))?,
                    )
                }
                "step" => {
                    selector = Some(Selector::Step(
                        rest.trim()
                            .parse()
                            .with_context(|| format!("line {}: bad step", ln + 1))?,
                    ))
                }
                "lambda" => {
                    let l: f64 = rest
                        .trim()
                        .parse()
                        .with_context(|| format!("line {}: bad lambda", ln + 1))?;
                    selector = Some(Selector::Lambda(l));
                }
                "auto" => {
                    let c = Criterion::from_name(rest.trim())
                        .with_context(|| format!("line {}: bad auto criterion", ln + 1))?;
                    selector = Some(Selector::Auto(c));
                }
                "x" => {
                    let row: Vec<f64> = rest
                        .split_whitespace()
                        .map(|t| t.parse::<f64>())
                        .collect::<std::result::Result<_, _>>()
                        .with_context(|| format!("line {}: bad x row", ln + 1))?;
                    rows.push(row);
                }
                other => bail!("line {}: unknown key '{other}'", ln + 1),
            }
        }
        let model = model.context("missing 'model' line")?;
        let selector = selector.context("missing 'step' or 'lambda' line")?;
        if rows.is_empty() {
            bail!("no 'x' query rows");
        }
        Ok(PredictRequest { model, selector, rows })
    }
}

// ── line protocol: /fit ─────────────────────────────────────────────

/// Body of `POST /fit` (every line optional; defaults below). The
/// request is the wire face of a [`crate::fit::FitSpec`]: `algo`, `t`,
/// `b`, `p`, `tol`, and `lambda_min` resolve into the spec via
/// [`FitRequest::to_spec`]; `name`, `dataset`, and `seed` are the
/// serving-side job bindings.
///
/// ```text
/// name sector-60
/// algo blars
/// dataset sector
/// t 60
/// b 4
/// p 8
/// seed 42
/// tol 1e-12
/// lambda_min 1e-6
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct FitRequest {
    pub name: String,
    pub algo: String,
    pub dataset: String,
    pub t: usize,
    pub b: usize,
    pub p: usize,
    pub seed: u64,
    /// Numerical floor (the spec's `tol`).
    pub tol: f64,
    /// λ floor for `algo lasso` (ignored by the other algorithms).
    pub lambda_min: f64,
}

impl Default for FitRequest {
    fn default() -> Self {
        FitRequest {
            name: String::new(),
            algo: "lars".to_string(),
            dataset: "tiny".to_string(),
            t: 16,
            b: 1,
            p: 4,
            seed: 42,
            tol: 1e-12,
            lambda_min: 1e-6,
        }
    }
}

impl FitRequest {
    pub fn encode(&self) -> String {
        let mut s = String::new();
        if !self.name.is_empty() {
            s.push_str(&format!("name {}\n", self.name));
        }
        s.push_str(&format!("algo {}\n", self.algo));
        s.push_str(&format!("dataset {}\n", self.dataset));
        s.push_str(&format!("t {}\n", self.t));
        s.push_str(&format!("b {}\n", self.b));
        s.push_str(&format!("p {}\n", self.p));
        s.push_str(&format!("seed {}\n", self.seed));
        s.push_str(&format!("tol {}\n", self.tol));
        s.push_str(&format!("lambda_min {}\n", self.lambda_min));
        s
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut out = FitRequest::default();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            let (key, rest) = line.split_once(' ').unwrap_or((line, ""));
            let rest = rest.trim();
            let bad = |what: &str| format!("line {}: bad {what}", ln + 1);
            match key {
                "name" => out.name = rest.to_string(),
                "algo" => out.algo = rest.to_string(),
                "dataset" => out.dataset = rest.to_string(),
                "t" => out.t = rest.parse().with_context(|| bad("t"))?,
                "b" => out.b = rest.parse().with_context(|| bad("b"))?,
                "p" => out.p = rest.parse().with_context(|| bad("p"))?,
                "seed" => out.seed = rest.parse().with_context(|| bad("seed"))?,
                "tol" => out.tol = rest.parse().with_context(|| bad("tol"))?,
                "lambda_min" => {
                    out.lambda_min = rest.parse().with_context(|| bad("lambda_min"))?
                }
                other => bail!("line {}: unknown key '{other}'", ln + 1),
            }
        }
        Ok(out)
    }

    /// Resolve the request's algorithm knobs into a validated
    /// [`crate::fit::FitSpec`]. Unknown algorithms and out-of-range
    /// knobs come back as typed
    /// [`crate::error::ErrorKind::InvalidSpec`] errors, which the HTTP
    /// layer maps to 400.
    pub fn to_spec(&self) -> Result<crate::fit::FitSpec> {
        let algorithm =
            crate::fit::Algorithm::from_parts(&self.algo, self.b, self.p, self.lambda_min)?;
        let spec = crate::fit::FitSpec::new(algorithm)
            .t(self.t)
            .tol(self.tol)
            .ranks(self.p);
        spec.validate()?;
        Ok(spec)
    }
}

// ── line protocol: bulk /fit ────────────────────────────────────────

/// Body of a **bulk** `POST /fit` — the same knob lines as
/// [`FitRequest`] plus one `y` row per response and (optionally) one
/// `names` line. The presence of any `y` row is what switches the
/// endpoint into batch mode ([`is_batch_fit`]); the design matrix
/// still comes from `dataset`, but the dataset's own response vector
/// is ignored in favor of the posted panel. All responses fit in one
/// [`crate::fit::FitSpec::fit_batch`] lockstep call and register in
/// one registry transaction.
///
/// ```text
/// name panel
/// algo lars
/// dataset tiny
/// t 8
/// names west east
/// y 0.1 0.2 0.3 …     # one row per response, each of length m
/// y 1.0 0.5 0.25 …
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct BatchFitRequest {
    /// The shared knobs (`name` becomes the base display name).
    pub base: FitRequest,
    /// Explicit per-response model names (empty → generated from
    /// `base.name`); when non-empty, must match the response count.
    pub names: Vec<String>,
    /// One response vector per model, row order = registration order.
    pub responses: Vec<Vec<f64>>,
}

/// True if a `POST /fit` body is a bulk request (has a `y` row).
pub fn is_batch_fit(body: &str) -> bool {
    body.lines().any(|l| {
        let t = l.trim_start();
        t == "y" || t.starts_with("y ")
    })
}

impl BatchFitRequest {
    pub fn encode(&self) -> String {
        let mut s = self.base.encode();
        if !self.names.is_empty() {
            s.push_str("names");
            for n in &self.names {
                s.push(' ');
                s.push_str(n);
            }
            s.push('\n');
        }
        for row in &self.responses {
            s.push('y');
            for v in row {
                s.push(' ');
                s.push_str(&v.to_string());
            }
            s.push('\n');
        }
        s
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut names: Vec<String> = Vec::new();
        let mut responses: Vec<Vec<f64>> = Vec::new();
        let mut base_lines = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            let (key, rest) = line.split_once(' ').unwrap_or((line, ""));
            match key {
                "y" => {
                    let row: Vec<f64> = rest
                        .split_whitespace()
                        .map(|t| t.parse::<f64>())
                        .collect::<std::result::Result<_, _>>()
                        .with_context(|| format!("line {}: bad y row", ln + 1))?;
                    if row.is_empty() {
                        bail!("line {}: empty y row", ln + 1);
                    }
                    responses.push(row);
                }
                "names" => {
                    names = rest.split_whitespace().map(str::to_string).collect();
                }
                _ => {
                    base_lines.push_str(raw);
                    base_lines.push('\n');
                }
            }
        }
        let base = FitRequest::parse(&base_lines)?;
        if responses.is_empty() {
            bail!("bulk fit needs at least one 'y' response row");
        }
        if !names.is_empty() && names.len() != responses.len() {
            bail!("{} names for {} y rows", names.len(), responses.len());
        }
        Ok(BatchFitRequest { base, names, responses })
    }

    /// Per-response display names: the explicit `names` when given,
    /// otherwise `<base>-<index>` from the base request's `name` (with
    /// `"batch"` standing in when that is empty too).
    pub fn model_names(&self) -> Vec<String> {
        if !self.names.is_empty() {
            return self.names.clone();
        }
        let stem = if self.base.name.is_empty() { "batch" } else { &self.base.name };
        (0..self.responses.len()).map(|i| format!("{stem}-{i}")).collect()
    }
}

// ── line protocol: /select ──────────────────────────────────────────

/// Body of `POST /select` — choose a serving step on a stored model's
/// path (`k`/`seed` only matter for `criterion cv`).
///
/// ```text
/// model 3
/// criterion cv    # cp | aic | bic | cv
/// k 5
/// seed 0
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct SelectRequest {
    pub model: u64,
    pub criterion: Criterion,
    pub k: usize,
    pub seed: u64,
}

impl SelectRequest {
    pub fn encode(&self) -> String {
        let mut s = format!("model {}\ncriterion {}\n", self.model, self.criterion.name());
        if self.criterion == Criterion::Cv {
            s.push_str(&format!("k {}\nseed {}\n", self.k, self.seed));
        }
        s
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut model: Option<u64> = None;
        let mut criterion: Option<Criterion> = None;
        let mut k = 5usize;
        let mut seed = 0u64;
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            let (key, rest) = line.split_once(' ').unwrap_or((line, ""));
            let rest = rest.trim();
            let bad = |what: &str| format!("line {}: bad {what}", ln + 1);
            match key {
                "model" => model = Some(rest.parse().with_context(|| bad("model id"))?),
                "criterion" => {
                    criterion =
                        Some(Criterion::from_name(rest).with_context(|| bad("criterion"))?)
                }
                "k" => k = rest.parse().with_context(|| bad("k"))?,
                "seed" => seed = rest.parse().with_context(|| bad("seed"))?,
                other => bail!("line {}: unknown key '{other}'", ln + 1),
            }
        }
        let req = SelectRequest {
            model: model.context("missing 'model' line")?,
            criterion: criterion.context("missing 'criterion' line")?,
            k,
            seed,
        };
        req.to_spec()?; // validate the CV knobs up front
        Ok(req)
    }

    /// Resolve into a validated [`SelectSpec`].
    pub fn to_spec(&self) -> Result<SelectSpec> {
        let spec = SelectSpec::new(self.criterion).k(self.k).seed(self.seed);
        spec.validate()?;
        Ok(spec)
    }
}

// ── HTTP/1.1 framing ────────────────────────────────────────────────

/// Largest accepted body (guards a malformed Content-Length).
const MAX_BODY: usize = 64 << 20;
const MAX_HEADERS: usize = 100;
/// Largest accepted request/status/header line — a peer streaming
/// bytes with no newline must not grow server memory unboundedly.
const MAX_LINE: usize = 64 << 10;

/// Read one `\n`-terminated line with a hard length cap. `Ok(None)`
/// = EOF before any byte.
fn read_line_capped(r: &mut impl BufRead) -> Result<Option<String>> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let (used, done) = {
            let available = r.fill_buf()?;
            if available.is_empty() {
                (0, true) // EOF; whatever is buffered is the final line
            } else if let Some(i) = available.iter().position(|&b| b == b'\n') {
                // audit: allow(PANIC-REACH) -- i is a position() hit on this very slice, so ..=i is in bounds
                buf.extend_from_slice(&available[..=i]);
                (i + 1, true)
            } else {
                buf.extend_from_slice(available);
                (available.len(), false)
            }
        };
        r.consume(used);
        if done {
            if used == 0 && buf.is_empty() {
                return Ok(None);
            }
            break;
        }
        if buf.len() > MAX_LINE {
            bail!("protocol line exceeds the {MAX_LINE} byte cap");
        }
    }
    String::from_utf8(buf).context("non-UTF-8 bytes in protocol line").map(Some)
}

/// A parsed request.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Decoded `k=v` pairs from the query string.
    pub query: Vec<(String, String)>,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl HttpRequest {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn query_get(&self, key: &str) -> Option<&str> {
        self.query.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// True for `?key=1`/`?key=true`/bare `?key`.
    pub fn query_flag(&self, key: &str) -> bool {
        match self.query_get(key) {
            Some(v) => v == "1" || v == "true" || v.is_empty(),
            None => false,
        }
    }
}

/// Read one request off a keep-alive connection. `Ok(None)` = clean EOF
/// (the peer closed between requests).
pub fn read_http_request(r: &mut impl BufRead) -> Result<Option<HttpRequest>> {
    let Some(line) = read_line_capped(r)? else {
        return Ok(None);
    };
    let start = line.trim_end();
    let mut parts = start.split_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v)) => (m.to_string(), t.to_string(), v),
        _ => bail!("malformed request line '{start}'"),
    };
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol version '{version}'");
    }
    let headers = read_headers(r)?;
    let body = read_body(r, &headers)?;
    let (path, query) = split_target(&target);
    Ok(Some(HttpRequest { method, path, query, headers, body }))
}

/// Serialize a response with `Content-Length` framing.
pub fn http_response(status: u16, content_type: &str, body: &str) -> String {
    format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n{body}",
        reason(status),
        body.len()
    )
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        422 => "Unprocessable Entity",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Status",
    }
}

/// Client side: read one `(status, body)` response.
pub fn read_http_response(r: &mut impl BufRead) -> Result<(u16, String)> {
    let line = read_line_capped(r)?.context("connection closed before response")?;
    let start = line.trim_end();
    let mut parts = start.split_whitespace();
    let version = parts.next().context("empty status line")?;
    if !version.starts_with("HTTP/1.") {
        bail!("malformed status line '{start}'");
    }
    let status: u16 = parts
        .next()
        .context("missing status code")?
        .parse()
        .with_context(|| format!("bad status code in '{start}'"))?;
    let headers = read_headers(r)?;
    let body = read_body(r, &headers)?;
    Ok((status, body))
}

fn read_headers(r: &mut impl BufRead) -> Result<Vec<(String, String)>> {
    let mut headers = Vec::new();
    loop {
        let line = read_line_capped(r)?.context("connection closed inside headers")?;
        let line = line.trim_end();
        if line.is_empty() {
            return Ok(headers);
        }
        if headers.len() >= MAX_HEADERS {
            bail!("too many headers");
        }
        let (k, v) = line.split_once(':').with_context(|| format!("malformed header '{line}'"))?;
        headers.push((k.trim().to_string(), v.trim().to_string()));
    }
}

fn read_body(r: &mut impl BufRead, headers: &[(String, String)]) -> Result<String> {
    let len: usize = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .map(|(_, v)| v.parse().context("bad Content-Length"))
        .transpose()?
        .unwrap_or(0);
    if len > MAX_BODY {
        bail!("body of {len} bytes exceeds the {MAX_BODY} byte cap");
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).context("short body")?;
    String::from_utf8(buf).context("body is not UTF-8")
}

fn split_target(target: &str) -> (String, Vec<(String, String)>) {
    match target.split_once('?') {
        None => (target.to_string(), Vec::new()),
        Some((path, qs)) => {
            let query = qs
                .split('&')
                .filter(|kv| !kv.is_empty())
                .map(|kv| match kv.split_once('=') {
                    Some((k, v)) => (k.to_string(), v.to_string()),
                    None => (kv.to_string(), String::new()),
                })
                .collect();
            (path.to_string(), query)
        }
    }
}

// ── minimal JSON ────────────────────────────────────────────────────

/// Escape a string for inclusion in a JSON string literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON number for an f64 (`null` for non-finite values) — delegates
/// to the crate-wide canonical formatter
/// [`crate::metrics::json_f64`]; kept re-exported here because every
/// serve-layer emitter imports it from the protocol module.
pub fn json_f64(v: f64) -> String {
    crate::metrics::json_f64(v)
}

/// Scan our own emitted JSON for `"key": <u64>` (good enough for the
/// in-tree client; not a general JSON parser).
pub fn json_find_u64(body: &str, key: &str) -> Option<u64> {
    let needle = format!("\"{key}\":");
    let at = body.find(&needle)? + needle.len();
    let rest = body.get(at..)?.trim_start();
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest.get(..end)?.parse().ok()
}

/// Scan for `"key": "<string>"` (no unescaping — our emitted values
/// are plain words).
pub fn json_find_str<'a>(body: &'a str, key: &str) -> Option<&'a str> {
    let needle = format!("\"{key}\":");
    let at = body.find(&needle)? + needle.len();
    let rest = body.get(at..)?.trim_start().strip_prefix('"')?;
    let end = rest.find('"')?;
    rest.get(..end)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    #[test]
    fn predict_round_trip_exact() {
        let req = PredictRequest {
            model: 7,
            selector: Selector::Step(3),
            rows: vec![vec![0.1, -2.5, 3.0], vec![1.0 / 3.0, f64::MIN_POSITIVE, 0.0]],
        };
        let back = PredictRequest::parse(&req.encode()).unwrap();
        assert_eq!(back, req, "encode → parse must be exact (Display round-trips f64)");
        let req_l = PredictRequest { selector: Selector::Lambda(0.12345678901234567), ..req };
        let back = PredictRequest::parse(&req_l.encode()).unwrap();
        assert_eq!(back, req_l);
    }

    #[test]
    fn predict_parse_rejects_malformed() {
        assert!(PredictRequest::parse("step 1\nx 1 2\n").is_err(), "missing model");
        assert!(PredictRequest::parse("model 1\nx 1 2\n").is_err(), "missing selector");
        assert!(PredictRequest::parse("model 1\nstep 2\n").is_err(), "no rows");
        assert!(PredictRequest::parse("model 1\nstep 2\nx 1 two\n").is_err(), "bad float");
        assert!(PredictRequest::parse("model 1\nstep 2\nbogus 3\nx 1\n").is_err());
        assert!(PredictRequest::parse("model 1\nauto r2\nx 1\n").is_err(), "bad criterion");
    }

    #[test]
    fn predict_auto_selector_round_trips() {
        for c in [Criterion::Cp, Criterion::Aic, Criterion::Bic, Criterion::Cv] {
            let req = PredictRequest {
                model: 3,
                selector: Selector::Auto(c),
                rows: vec![vec![1.0, 2.0]],
            };
            assert_eq!(PredictRequest::parse(&req.encode()).unwrap(), req, "{c:?}");
        }
    }

    #[test]
    fn select_request_round_trips_and_validates() {
        let cv = SelectRequest { model: 7, criterion: Criterion::Cv, k: 4, seed: 9 };
        assert_eq!(SelectRequest::parse(&cv.encode()).unwrap(), cv);
        let cp = SelectRequest { model: 2, criterion: Criterion::Cp, k: 5, seed: 0 };
        assert_eq!(SelectRequest::parse(&cp.encode()).unwrap(), cp);
        assert!(SelectRequest::parse("criterion cp\n").is_err(), "missing model");
        assert!(SelectRequest::parse("model 1\n").is_err(), "missing criterion");
        assert!(SelectRequest::parse("model 1\ncriterion r2\n").is_err());
        assert!(SelectRequest::parse("model 1\ncriterion cv\nk 1\n").is_err(), "k < 2");
        assert!(SelectRequest::parse("model 1\ncriterion cp\nbogus 2\n").is_err());
        let spec = cv.to_spec().unwrap();
        assert_eq!((spec.criterion, spec.k, spec.seed), (Criterion::Cv, 4, 9));
    }

    #[test]
    fn fit_round_trip_and_defaults() {
        let req = FitRequest {
            name: "sector-60".into(),
            algo: "blars".into(),
            dataset: "sector".into(),
            t: 60,
            b: 4,
            p: 8,
            seed: 9,
            tol: 1e-10,
            lambda_min: 2.5e-7,
        };
        assert_eq!(FitRequest::parse(&req.encode()).unwrap(), req);
        let d = FitRequest::parse("").unwrap();
        assert_eq!(d, FitRequest::default());
        assert_eq!(FitRequest::parse("t 5\n").unwrap().t, 5);
    }

    #[test]
    fn fit_request_resolves_to_validated_spec() {
        use crate::error::ErrorKind;
        use crate::fit::Algorithm;
        let req = FitRequest { algo: "blars".into(), b: 3, p: 8, t: 24, ..Default::default() };
        let spec = req.to_spec().unwrap();
        assert_eq!(spec.algorithm, Algorithm::Blars { b: 3 });
        assert_eq!(spec.t, 24);
        assert_eq!(spec.ranks, 8);

        let lasso = FitRequest { algo: "lasso".into(), lambda_min: 1e-4, ..Default::default() };
        assert_eq!(
            lasso.to_spec().unwrap().algorithm,
            Algorithm::LassoLars { lambda_min: 1e-4 }
        );

        let bad_algo = FitRequest { algo: "ridge".into(), ..Default::default() };
        assert_eq!(bad_algo.to_spec().unwrap_err().kind(), ErrorKind::InvalidSpec);
        let bad_b = FitRequest { algo: "blars".into(), b: 0, ..Default::default() };
        assert_eq!(bad_b.to_spec().unwrap_err().kind(), ErrorKind::InvalidSpec);
        let bad_t = FitRequest { t: 0, ..Default::default() };
        assert_eq!(bad_t.to_spec().unwrap_err().kind(), ErrorKind::InvalidSpec);
        let bad_p = FitRequest { p: 0, ..Default::default() };
        assert_eq!(
            bad_p.to_spec().unwrap_err().kind(),
            ErrorKind::InvalidSpec,
            "p=0 must be rejected like every other out-of-range knob"
        );
    }

    #[test]
    fn batch_fit_round_trips_and_validates() {
        let req = BatchFitRequest {
            base: FitRequest { name: "panel".into(), t: 8, ..Default::default() },
            names: vec!["west".into(), "east".into()],
            responses: vec![vec![0.25, -1.5, 3.0], vec![1.0 / 3.0, 0.0, 2.0]],
        };
        let wire = req.encode();
        assert!(is_batch_fit(&wire));
        assert_eq!(BatchFitRequest::parse(&wire).unwrap(), req);
        assert_eq!(req.model_names(), vec!["west", "east"]);

        let unnamed = BatchFitRequest { names: Vec::new(), ..req };
        assert_eq!(BatchFitRequest::parse(&unnamed.encode()).unwrap(), unnamed);
        assert_eq!(unnamed.model_names(), vec!["panel-0", "panel-1"]);

        assert!(!is_batch_fit("algo lars\nt 8\n"), "plain fits are not batches");
        assert!(!is_batch_fit("yolo 1\n"), "only a y key counts");
        assert!(BatchFitRequest::parse("algo lars\n").is_err(), "no y rows");
        assert!(BatchFitRequest::parse("y 1 two\n").is_err(), "bad float");
        assert!(BatchFitRequest::parse("y\n").is_err(), "empty row");
        assert!(
            BatchFitRequest::parse("names a b c\ny 1 2\ny 3 4\n").is_err(),
            "name/row count mismatch"
        );
    }

    #[test]
    fn http_request_round_trip_with_body_and_query() {
        let body = "model 1\nstep 2\nx 1 2 3\n";
        let wire = format!(
            "POST /predict?wait=1&tag=x HTTP/1.1\r\nHost: h\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let mut r = BufReader::new(wire.as_bytes());
        let req = read_http_request(&mut r).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/predict");
        assert!(req.query_flag("wait"));
        assert_eq!(req.query_get("tag"), Some("x"));
        assert_eq!(req.header("host"), Some("h"));
        assert_eq!(req.body, body);
        // Clean EOF after the request → None.
        assert!(read_http_request(&mut r).unwrap().is_none());
    }

    #[test]
    fn http_response_round_trip() {
        let wire = http_response(200, "application/json", "{\"ok\":true}");
        let mut r = BufReader::new(wire.as_bytes());
        let (status, body) = read_http_response(&mut r).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "{\"ok\":true}");
    }

    #[test]
    fn http_rejects_malformed() {
        let mut r = BufReader::new(&b"NOT A REQUEST\r\n\r\n"[..]);
        assert!(read_http_request(&mut r).is_err());
        let mut r = BufReader::new(&b"GET / HTTP/1.1\r\nContent-Length: 99\r\n\r\nshort"[..]);
        assert!(read_http_request(&mut r).is_err(), "short body");
    }

    #[test]
    fn endless_line_without_newline_is_capped() {
        // A peer streaming bytes with no '\n' must hit the line cap,
        // not grow server memory without bound.
        let garbage = vec![b'a'; MAX_LINE + 1024];
        let mut r = BufReader::new(garbage.as_slice());
        let err = read_http_request(&mut r).unwrap_err();
        assert!(format!("{err:#}").contains("cap"), "{err:#}");
        // Same guard inside headers.
        let mut wire = b"GET / HTTP/1.1\r\nX-Big: ".to_vec();
        wire.extend(vec![b'x'; MAX_LINE + 1024]);
        let mut r = BufReader::new(wire.as_slice());
        assert!(read_http_request(&mut r).is_err());
    }

    #[test]
    fn json_helpers() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_f64(1.5), "1.5");
        assert_eq!(json_f64(f64::NAN), "null");
        let body = "{\"job\": 12, \"state\": \"done\", \"model\": 3}";
        assert_eq!(json_find_u64(body, "job"), Some(12));
        assert_eq!(json_find_u64(body, "model"), Some(3));
        assert_eq!(json_find_u64(body, "missing"), None);
        assert_eq!(json_find_str(body, "state"), Some("done"));
    }
}
