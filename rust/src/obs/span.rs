//! Thread-local span stack + bounded global [`TraceSink`].
//!
//! A span is opened with [`span`]/[`phase_span`] and closed by dropping
//! the returned [`SpanGuard`]. Closed spans are buffered in a
//! thread-local vector and drained into the process-wide sink when the
//! thread's trace binding is released (one lock acquisition per
//! request, amortized — the hot fit loop itself never takes a lock) or
//! when the local buffer fills.
//!
//! Cost model: when tracing is disabled ([`enabled`] is false) or the
//! calling thread has no trace bound, opening a span is one relaxed
//! atomic load plus one thread-local read and the guard is inert.
//! Instrumentation must never change numeric results — spans only
//! observe the clock (see the bit-identity test in `tests/obs.rs`).

use std::cell::{Cell, RefCell};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use crate::cluster::tracer::Phase;

/// Global enable switch, initialized once from `CALARS_TRACE`
/// (`off`/`0`/`false`/`no` disable; anything else — including unset —
/// enables).
fn enabled_flag() -> &'static AtomicBool {
    static FLAG: OnceLock<AtomicBool> = OnceLock::new();
    FLAG.get_or_init(|| {
        let on = match std::env::var("CALARS_TRACE") {
            Ok(v) => {
                let v = v.to_ascii_lowercase();
                !(v == "off" || v == "0" || v == "false" || v == "no")
            }
            Err(_) => true,
        };
        AtomicBool::new(on)
    })
}

/// Whether span recording is currently on.
pub fn enabled() -> bool {
    enabled_flag().load(Ordering::Relaxed)
}

/// Override the `CALARS_TRACE` switch at runtime (used by
/// `calars trace` and the test suite).
pub fn set_enabled(on: bool) {
    enabled_flag().store(on, Ordering::Relaxed);
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Monotonic nanoseconds since the process trace epoch.
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);

/// Allocate a fresh nonzero trace id (one per request / CLI fit).
pub fn next_trace_id() -> u64 {
    NEXT_TRACE.fetch_add(1, Ordering::Relaxed)
}

/// The wire form echoed in JSON responses: 16 lowercase hex digits.
pub fn format_trace_id(id: u64) -> String {
    format!("{id:016x}")
}

/// Inverse of [`format_trace_id`]; `None` for malformed or zero ids.
pub fn parse_trace_id(s: &str) -> Option<u64> {
    if s.is_empty() || s.len() > 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok().filter(|&v| v != 0)
}

/// One closed span (or zero-duration marker), as stored in the sink.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    /// Owning trace (never 0 once recorded).
    pub trace: u64,
    pub name: &'static str,
    /// Set for fit-loop spans that map onto the paper's phase taxonomy.
    pub phase: Option<Phase>,
    /// Nanoseconds since the process trace epoch.
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Stable small per-thread ordinal (not the OS tid).
    pub tid: u64,
    /// Nesting depth at open time on the recording thread (root = 0).
    pub depth: u32,
    /// Coarse flop estimate attached by the instrumentation site.
    pub flops: u64,
}

thread_local! {
    /// Trace id bound to this thread (0 = untraced).
    static CURRENT: Cell<u64> = const { Cell::new(0) };
    /// Open-span nesting depth on this thread.
    static DEPTH: Cell<u32> = const { Cell::new(0) };
    /// Closed spans buffered locally; drained per request.
    static BUFFER: RefCell<Vec<SpanRecord>> = const { RefCell::new(Vec::new()) };
    /// Stable small ordinal for this thread (assigned on first record).
    static TID: Cell<u64> = const { Cell::new(0) };
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

fn thread_ordinal() -> u64 {
    TID.with(|t| {
        let v = t.get();
        if v != 0 {
            return v;
        }
        let v = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        t.set(v);
        v
    })
}

/// The trace id bound to the calling thread (0 when untraced).
pub fn current_trace() -> u64 {
    CURRENT.with(|c| c.get())
}

/// Bind `trace` as the calling thread's ambient trace id, returning
/// the previous binding for [`uninstall_trace`]. Prefer [`with_trace`];
/// this split form exists for observers whose install/release points
/// live in separate callbacks.
pub fn install_trace(trace: u64) -> u64 {
    CURRENT.with(|c| c.replace(trace))
}

/// Restore a binding saved by [`install_trace`] and flush this
/// thread's buffered spans into the sink.
pub fn uninstall_trace(prev: u64) {
    CURRENT.with(|c| c.set(prev));
    flush_thread();
}

/// Run `f` with `trace` bound on this thread. The buffer is flushed on
/// exit even if `f` panics (drop guard), so a crashed fit still leaves
/// its partial trace inspectable.
pub fn with_trace<R>(trace: u64, f: impl FnOnce() -> R) -> R {
    struct Reset(u64);
    impl Drop for Reset {
        fn drop(&mut self) {
            uninstall_trace(self.0);
        }
    }
    let _reset = Reset(install_trace(trace));
    f()
}

struct OpenSpan {
    trace: u64,
    name: &'static str,
    phase: Option<Phase>,
    start_ns: u64,
    depth: u32,
    flops: u64,
}

/// RAII timer for one span; inert when tracing is off or no trace is
/// bound.
pub struct SpanGuard {
    open: Option<OpenSpan>,
}

impl SpanGuard {
    /// Attach a coarse flop count to the span (additive; no-op when
    /// the guard is inert).
    pub fn flops(&mut self, n: u64) {
        if let Some(s) = self.open.as_mut() {
            s.flops += n;
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(s) = self.open.take() else { return };
        let end = now_ns();
        DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
        push_record(SpanRecord {
            trace: s.trace,
            name: s.name,
            phase: s.phase,
            start_ns: s.start_ns,
            dur_ns: end.saturating_sub(s.start_ns),
            tid: thread_ordinal(),
            depth: s.depth,
            flops: s.flops,
        });
    }
}

fn open_span(name: &'static str, phase: Option<Phase>) -> SpanGuard {
    if !enabled() {
        return SpanGuard { open: None };
    }
    let trace = current_trace();
    if trace == 0 {
        return SpanGuard { open: None };
    }
    let depth = DEPTH.with(|d| {
        let v = d.get();
        d.set(v + 1);
        v
    });
    SpanGuard {
        open: Some(OpenSpan { trace, name, phase, start_ns: now_ns(), depth, flops: 0 }),
    }
}

/// Open a named span on the current trace.
pub fn span(name: &'static str) -> SpanGuard {
    open_span(name, None)
}

/// Open a span labeled with a fit-loop [`Phase`].
pub fn phase_span(phase: Phase) -> SpanGuard {
    open_span(phase.label(), Some(phase))
}

/// Record a zero-duration marker event (e.g. a Gram-panel cache hit).
pub fn instant(name: &'static str) {
    if !enabled() {
        return;
    }
    let trace = current_trace();
    if trace == 0 {
        return;
    }
    push_record(SpanRecord {
        trace,
        name,
        phase: None,
        start_ns: now_ns(),
        dur_ns: 0,
        tid: thread_ordinal(),
        depth: DEPTH.with(|d| d.get()),
        flops: 0,
    });
}

/// Record a span that ends now and started `dur_ns` ago — for
/// intervals timed outside the guard mechanism (e.g. queue wait,
/// measured from an enqueue stamp carried inside the job).
pub fn record_span_ending_now(name: &'static str, phase: Option<Phase>, dur_ns: u64) {
    if !enabled() {
        return;
    }
    let trace = current_trace();
    if trace == 0 {
        return;
    }
    let end = now_ns();
    push_record(SpanRecord {
        trace,
        name,
        phase,
        start_ns: end.saturating_sub(dur_ns),
        dur_ns,
        tid: thread_ordinal(),
        depth: DEPTH.with(|d| d.get()),
        flops: 0,
    });
}

/// Local buffer cap before an early flush — bounds the thread-local
/// vector for very long fits.
const FLUSH_AT: usize = 256;

fn push_record(rec: SpanRecord) {
    let len = BUFFER.with(|b| {
        let mut b = b.borrow_mut();
        b.push(rec);
        b.len()
    });
    if len >= FLUSH_AT {
        flush_thread();
    }
}

/// Drain this thread's buffered spans into the global sink. Happens
/// automatically when a trace binding is released or the buffer fills;
/// callers that record outside any binding scope (e.g. `calars trace`
/// after the observer detaches) invoke it explicitly.
pub fn flush_thread() {
    let drained = BUFFER.with(|b| std::mem::take(&mut *b.borrow_mut()));
    if !drained.is_empty() {
        sink().absorb(drained);
    }
}

/// Retention bounds for the global sink.
const MAX_TRACES: usize = 512;
const MAX_SPANS_PER_TRACE: usize = 4096;
const MAX_SLOW: usize = 128;

/// One entry in the ring-buffered slow-request log.
#[derive(Clone, Debug)]
pub struct SlowEntry {
    pub trace: u64,
    /// `"METHOD /path"` of the offending request.
    pub what: String,
    pub dur_ns: u64,
}

/// Point-in-time counters for the sink (rendered under `/metrics`).
#[derive(Clone, Copy, Debug, Default)]
pub struct SinkStats {
    /// Traces currently retained.
    pub traces: u64,
    /// Spans currently retained across all traces.
    pub spans: u64,
    /// Spans absorbed since process start (monotone).
    pub recorded: u64,
    /// Traces dropped to stay within the retention bound (monotone) —
    /// lets clients distinguish "evicted" from "never recorded".
    pub evicted: u64,
    pub slow_entries: u64,
}

struct SinkInner {
    traces: HashMap<u64, Vec<SpanRecord>>,
    /// Insertion order for FIFO eviction.
    order: VecDeque<u64>,
    slow: VecDeque<SlowEntry>,
}

/// Bounded global store of completed trace spans, keyed by trace id.
pub struct TraceSink {
    inner: Mutex<SinkInner>,
    recorded: AtomicU64,
    evicted: AtomicU64,
}

/// The process-wide sink behind `/trace/<id>` and `calars trace`.
pub fn sink() -> &'static TraceSink {
    static SINK: OnceLock<TraceSink> = OnceLock::new();
    SINK.get_or_init(|| TraceSink {
        inner: Mutex::new(SinkInner {
            traces: HashMap::new(),
            order: VecDeque::new(),
            slow: VecDeque::new(),
        }),
        recorded: AtomicU64::new(0),
        evicted: AtomicU64::new(0),
    })
}

impl TraceSink {
    fn lock(&self) -> MutexGuard<'_, SinkInner> {
        // Span buffers are plain data; recover a poisoned sink rather
        // than cascading an unrelated panic into every scrape.
        match self.inner.lock() {
            Ok(g) => g,
            Err(e) => e.into_inner(),
        }
    }

    fn absorb(&self, spans: Vec<SpanRecord>) {
        self.recorded.fetch_add(spans.len() as u64, Ordering::Relaxed);
        let mut guard = self.lock();
        let inner = &mut *guard;
        for rec in spans {
            if !inner.traces.contains_key(&rec.trace) {
                inner.order.push_back(rec.trace);
                inner.traces.insert(rec.trace, Vec::new());
            }
            if let Some(v) = inner.traces.get_mut(&rec.trace) {
                if v.len() < MAX_SPANS_PER_TRACE {
                    v.push(rec);
                }
            }
        }
        while inner.order.len() > MAX_TRACES {
            if let Some(old) = inner.order.pop_front() {
                inner.traces.remove(&old);
                self.evicted.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// All spans recorded for `trace`, or `None` if unknown / evicted.
    pub fn get(&self, trace: u64) -> Option<Vec<SpanRecord>> {
        self.lock().traces.get(&trace).cloned()
    }

    /// Append to the ring-buffered slow-request log.
    pub fn note_slow(&self, trace: u64, what: String, dur_ns: u64) {
        let mut inner = self.lock();
        inner.slow.push_back(SlowEntry { trace, what, dur_ns });
        while inner.slow.len() > MAX_SLOW {
            inner.slow.pop_front();
        }
    }

    /// Snapshot of the slow-request log, oldest first.
    pub fn slow_log(&self) -> Vec<SlowEntry> {
        self.lock().slow.iter().cloned().collect()
    }

    pub fn stats(&self) -> SinkStats {
        let inner = self.lock();
        SinkStats {
            traces: inner.traces.len() as u64,
            spans: inner.traces.values().map(|v| v.len() as u64).sum(),
            recorded: self.recorded.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
            slow_entries: inner.slow.len() as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes the tests that flip the global enable switch or
    /// count sink totals (the test harness runs tests in parallel).
    static GATE: Mutex<()> = Mutex::new(());

    fn gate() -> MutexGuard<'static, ()> {
        match GATE.lock() {
            Ok(g) => g,
            Err(e) => e.into_inner(),
        }
    }

    #[test]
    fn spans_require_a_bound_trace() {
        let _g = gate();
        set_enabled(true);
        // No trace bound: guard is inert, nothing reaches the sink.
        let before = sink().stats().recorded;
        {
            let mut g = span("orphan");
            g.flops(10);
        }
        instant("orphan_marker");
        flush_thread();
        assert_eq!(sink().stats().recorded, before);
    }

    #[test]
    fn with_trace_records_and_flushes() {
        let _g = gate();
        set_enabled(true);
        let id = next_trace_id();
        with_trace(id, || {
            let mut outer = span("outer");
            outer.flops(7);
            {
                let _inner = phase_span(Phase::Corr);
            }
            instant("marker");
        });
        let spans = sink().get(id).expect("trace retained");
        assert_eq!(spans.len(), 3);
        let outer = spans.iter().find(|s| s.name == "outer").unwrap();
        assert_eq!(outer.depth, 0);
        assert_eq!(outer.flops, 7);
        let corr = spans.iter().find(|s| s.name == "Corr").unwrap();
        assert_eq!(corr.phase, Some(Phase::Corr));
        assert_eq!(corr.depth, 1);
        assert!(spans.iter().any(|s| s.name == "marker" && s.dur_ns == 0));
    }

    #[test]
    fn disabled_records_nothing() {
        let _g = gate();
        set_enabled(false);
        let id = next_trace_id();
        with_trace(id, || {
            let _g = span("quiet");
            instant("quiet_marker");
        });
        set_enabled(true);
        assert!(sink().get(id).is_none());
    }

    #[test]
    fn trace_id_round_trip() {
        let id = next_trace_id();
        let s = format_trace_id(id);
        assert_eq!(s.len(), 16);
        assert_eq!(parse_trace_id(&s), Some(id));
        assert_eq!(parse_trace_id(""), None);
        assert_eq!(parse_trace_id("zz"), None);
        assert_eq!(parse_trace_id("0"), None);
    }

    #[test]
    fn slow_log_is_bounded() {
        for i in 0..(MAX_SLOW + 10) {
            sink().note_slow(u64::MAX - i as u64, format!("GET /x{i}"), 1);
        }
        assert!(sink().slow_log().len() <= MAX_SLOW);
    }
}
