//! Typed metrics registry: counters, gauges, and fixed-bucket
//! histograms with a Prometheus text-exposition renderer.
//!
//! Registration (get-or-create by name + label set) takes a mutex, but
//! handles are `Arc`-backed atomics that call sites cache, so the hot
//! path — `inc`/`observe` — is lock-free. Histogram sums are `f64`
//! accumulated by a CAS loop on the bit pattern, which merges across
//! worker threads without locks.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotone counter handle; clones share the underlying cell.
#[derive(Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge handle (u64 levels: queue depths, sizes).
#[derive(Clone, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

struct HistogramCore {
    /// Ascending upper bounds; the `+Inf` bucket is implicit.
    bounds: Vec<f64>,
    /// `bounds.len() + 1` per-bucket counts (last = overflow).
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// f64 bit pattern, CAS-accumulated.
    sum_bits: AtomicU64,
}

/// Fixed-bucket histogram handle; clones share the underlying cells.
#[derive(Clone)]
pub struct Histogram(Arc<HistogramCore>);

impl Histogram {
    fn with_bounds(bounds: &[f64]) -> Self {
        let mut buckets = Vec::with_capacity(bounds.len() + 1);
        for _ in 0..=bounds.len() {
            buckets.push(AtomicU64::new(0));
        }
        Histogram(Arc::new(HistogramCore {
            bounds: bounds.to_vec(),
            buckets,
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }))
    }

    pub fn observe(&self, v: f64) {
        let c = &self.0;
        let idx = c.bounds.iter().position(|&b| v <= b).unwrap_or(c.bounds.len());
        c.buckets[idx].fetch_add(1, Ordering::Relaxed);
        c.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = c.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match c.sum_bits.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Observe a duration in seconds.
    pub fn observe_secs(&self, d: std::time::Duration) {
        self.observe(d.as_secs_f64());
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    pub fn bounds(&self) -> &[f64] {
        &self.0.bounds
    }

    /// Non-cumulative per-bucket counts (last entry = overflow bucket).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.0.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }
}

/// The latency bucket layout documented in DESIGN.md §Observability:
/// log-spaced powers of two from 16µs to ~16.8s (21 finite bounds),
/// one layout for every latency histogram so panels line up.
pub fn latency_bounds() -> Vec<f64> {
    (0..=20).map(|i| 16e-6 * (1u64 << i) as f64).collect()
}

#[derive(Clone)]
enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

struct Entry {
    name: String,
    /// Rendered inside `{}` (e.g. `route="/fit"`); empty for none.
    labels: String,
    help: &'static str,
    metric: Metric,
}

/// Registry of metric families; one global instance serves `/metrics`.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Vec<Entry>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry { inner: Mutex::new(Vec::new()) }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Vec<Entry>> {
        match self.inner.lock() {
            Ok(g) => g,
            Err(e) => e.into_inner(),
        }
    }

    fn get_or_insert(
        &self,
        name: &str,
        labels: &str,
        help: &'static str,
        make: impl FnOnce() -> Metric,
    ) -> Metric {
        let mut inner = self.lock();
        if let Some(e) = inner.iter().find(|e| e.name == name && e.labels == labels) {
            return e.metric.clone();
        }
        let metric = make();
        inner.push(Entry {
            name: name.to_string(),
            labels: labels.to_string(),
            help,
            metric: metric.clone(),
        });
        metric
    }

    /// Get or create a counter. By Prometheus convention the name
    /// should end in `_total`.
    pub fn counter(&self, name: &str, labels: &str, help: &'static str) -> Counter {
        match self.get_or_insert(name, labels, help, || Metric::Counter(Counter::default())) {
            Metric::Counter(c) => c,
            _ => Counter::default(), // name reused across kinds: unregistered fallback
        }
    }

    pub fn gauge(&self, name: &str, labels: &str, help: &'static str) -> Gauge {
        match self.get_or_insert(name, labels, help, || Metric::Gauge(Gauge::default())) {
            Metric::Gauge(g) => g,
            _ => Gauge::default(),
        }
    }

    pub fn histogram(
        &self,
        name: &str,
        labels: &str,
        help: &'static str,
        bounds: &[f64],
    ) -> Histogram {
        match self.get_or_insert(name, labels, help, || {
            Metric::Histogram(Histogram::with_bounds(bounds))
        }) {
            Metric::Histogram(h) => h,
            _ => Histogram::with_bounds(bounds),
        }
    }

    /// Render the whole registry as Prometheus text exposition
    /// (version 0.0.4): one `# HELP`/`# TYPE` header per family,
    /// followed by every labeled sample of that family, cumulative
    /// `le` buckets plus `_sum`/`_count` for histograms.
    pub fn render(&self) -> String {
        let inner = self.lock();
        let mut out = String::new();
        let mut seen: Vec<&str> = Vec::new();
        for e in inner.iter() {
            if seen.iter().any(|&s| s == e.name) {
                continue;
            }
            seen.push(&e.name);
            let kind = match &e.metric {
                Metric::Counter(_) => "counter",
                Metric::Gauge(_) => "gauge",
                Metric::Histogram(_) => "histogram",
            };
            out.push_str(&format!("# HELP {} {}\n", e.name, e.help));
            out.push_str(&format!("# TYPE {} {}\n", e.name, kind));
            for m in inner.iter().filter(|m| m.name == e.name) {
                render_sample(&mut out, m);
            }
        }
        out
    }
}

fn render_sample(out: &mut String, e: &Entry) {
    let braces = |labels: &str| {
        if labels.is_empty() {
            String::new()
        } else {
            format!("{{{labels}}}")
        }
    };
    match &e.metric {
        Metric::Counter(c) => {
            out.push_str(&format!("{}{} {}\n", e.name, braces(&e.labels), c.get()));
        }
        Metric::Gauge(g) => {
            out.push_str(&format!("{}{} {}\n", e.name, braces(&e.labels), g.get()));
        }
        Metric::Histogram(h) => {
            let join = |le: String| {
                if e.labels.is_empty() {
                    format!("le=\"{le}\"")
                } else {
                    format!("{},le=\"{le}\"", e.labels)
                }
            };
            let counts = h.bucket_counts();
            let mut cum = 0u64;
            for (i, b) in h.bounds().iter().enumerate() {
                cum += counts[i];
                out.push_str(&format!(
                    "{}_bucket{{{}}} {}\n",
                    e.name,
                    join(format!("{b}")),
                    cum
                ));
            }
            out.push_str(&format!(
                "{}_bucket{{{}}} {}\n",
                e.name,
                join("+Inf".to_string()),
                h.count()
            ));
            out.push_str(&format!(
                "{}_sum{} {}\n",
                e.name,
                braces(&e.labels),
                crate::metrics::json_f64(h.sum())
            ));
            out.push_str(&format!("{}_count{} {}\n", e.name, braces(&e.labels), h.count()));
        }
    }
}

/// The process-global registry backing `/metrics` and `/stats`.
pub fn global() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let r = Registry::new();
        let c = r.counter("calars_test_total", "", "test counter");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // Same (name, labels) returns the same cell.
        assert_eq!(r.counter("calars_test_total", "", "test counter").get(), 5);
        let g = r.gauge("calars_test_depth", "", "test gauge");
        g.set(17);
        assert_eq!(r.gauge("calars_test_depth", "", "test gauge").get(), 17);
    }

    #[test]
    fn histogram_buckets_and_sum() {
        let h = Histogram::with_bounds(&[0.001, 0.01, 0.1]);
        h.observe(0.0005);
        h.observe(0.005);
        h.observe(0.005);
        h.observe(5.0);
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 5.0105).abs() < 1e-12);
        assert_eq!(h.bucket_counts(), vec![1, 2, 0, 1]);
    }

    #[test]
    fn observe_is_mergeable_across_threads() {
        let h = Histogram::with_bounds(&latency_bounds());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..1000 {
                        h.observe(1e-5 * (1 + i % 7) as f64);
                    }
                });
            }
        });
        assert_eq!(h.count(), 4000);
        assert_eq!(h.bucket_counts().iter().sum::<u64>(), 4000);
        assert!(h.sum() > 0.0);
    }

    #[test]
    fn render_is_framed_per_family() {
        let r = Registry::new();
        r.counter("calars_reqs_total", "route=\"/fit\"", "requests").add(2);
        r.counter("calars_reqs_total", "route=\"/predict\"", "requests").add(3);
        r.gauge("calars_depth", "", "depth").set(1);
        let h = r.histogram("calars_lat_seconds", "", "latency", &[0.01, 0.1]);
        h.observe(0.005);
        h.observe(0.05);
        let text = r.render();
        // One TYPE header per family, samples grouped under it.
        assert_eq!(text.matches("# TYPE calars_reqs_total counter").count(), 1);
        assert!(text.contains("calars_reqs_total{route=\"/fit\"} 2"));
        assert!(text.contains("calars_reqs_total{route=\"/predict\"} 3"));
        assert!(text.contains("# TYPE calars_lat_seconds histogram"));
        assert!(text.contains("calars_lat_seconds_bucket{le=\"0.01\"} 1"));
        assert!(text.contains("calars_lat_seconds_bucket{le=\"0.1\"} 2"));
        assert!(text.contains("calars_lat_seconds_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("calars_lat_seconds_count 2"));
        assert!(text.ends_with('\n'));
    }

    #[test]
    fn latency_bounds_are_log_spaced_and_ascending() {
        let b = latency_bounds();
        assert_eq!(b.len(), 21);
        assert!((b[0] - 16e-6).abs() < 1e-12);
        for w in b.windows(2) {
            assert!(w[1] > w[0]);
            assert!((w[1] / w[0] - 2.0).abs() < 1e-9);
        }
    }
}
