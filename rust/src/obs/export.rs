//! Trace export: chrome://tracing JSON, an ASCII span tree, and
//! per-phase totals comparable with the SimCluster cost model.

use crate::cluster::tracer::{Phase, Tracer};

use super::span::SpanRecord;

/// Render spans as a chrome://tracing-loadable JSON document
/// (`traceEvents` array of complete `"X"` events plus instant `"i"`
/// markers; timestamps in microseconds since the process epoch). Span
/// names are static identifiers chosen by the instrumentation sites,
/// so no JSON escaping is required.
pub fn chrome_trace_json(spans: &[SpanRecord]) -> String {
    let mut out = String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    for (i, s) in spans.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let cat = match s.phase {
            Some(p) => p.label(),
            None => "span",
        };
        let ts = s.start_ns as f64 / 1e3;
        if s.dur_ns == 0 && s.phase.is_none() {
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"cat\":\"marker\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{ts:.3},\"pid\":1,\"tid\":{}}}",
                s.name, s.tid
            ));
        } else {
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"cat\":\"{cat}\",\"ph\":\"X\",\"ts\":{ts:.3},\"dur\":{:.3},\"pid\":1,\"tid\":{},\"args\":{{\"flops\":{}}}}}",
                s.name,
                s.dur_ns as f64 / 1e3,
                s.tid,
                s.flops
            ));
        }
    }
    out.push_str("]}");
    out
}

/// Pretty-print spans as a per-thread tree, indented by recorded
/// nesting depth, with durations and flop annotations. Used by
/// `calars trace`.
pub fn span_tree(spans: &[SpanRecord]) -> String {
    let mut tids: Vec<u64> = spans.iter().map(|s| s.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    let mut out = String::new();
    for tid in tids {
        let mut rows: Vec<&SpanRecord> = spans.iter().filter(|s| s.tid == tid).collect();
        // Start-time order; ties broken longest-first so parents print
        // before the children they enclose.
        rows.sort_by(|a, b| {
            (a.start_ns, std::cmp::Reverse(a.dur_ns)).cmp(&(b.start_ns, std::cmp::Reverse(b.dur_ns)))
        });
        out.push_str(&format!("thread {tid}\n"));
        for s in rows {
            let indent = "  ".repeat(s.depth as usize + 1);
            if s.dur_ns == 0 && s.phase.is_none() {
                out.push_str(&format!("{indent}* {}\n", s.name));
                continue;
            }
            let ms = s.dur_ns as f64 / 1e6;
            if s.flops > 0 {
                out.push_str(&format!(
                    "{indent}{:<14} {:>10.3} ms  {} flops\n",
                    s.name, ms, s.flops
                ));
            } else {
                out.push_str(&format!("{indent}{:<14} {:>10.3} ms\n", s.name, ms));
            }
        }
    }
    out
}

const NPHASES: usize = Phase::ALL.len();

/// Measured wall-time and flop totals per [`Phase`] — the real-hardware
/// counterpart of the SimCluster [`Tracer`], so a measured `/fit` trace
/// and a simulated schedule can be compared phase-for-phase.
#[derive(Clone, Debug, Default)]
pub struct PhaseTotals {
    time: [f64; NPHASES],
    flops: [u64; NPHASES],
}

fn idx(phase: Phase) -> usize {
    Phase::ALL.iter().position(|&p| p == phase).unwrap_or(NPHASES - 1)
}

impl PhaseTotals {
    /// Aggregate measured spans (spans without a phase are skipped).
    pub fn from_spans(spans: &[SpanRecord]) -> Self {
        let mut t = PhaseTotals::default();
        for s in spans {
            if let Some(p) = s.phase {
                let i = idx(p);
                t.time[i] += s.dur_ns as f64 / 1e9;
                t.flops[i] += s.flops;
            }
        }
        t
    }

    /// Project a simulated [`Tracer`] onto the same table shape.
    pub fn from_tracer(tr: &Tracer) -> Self {
        let mut t = PhaseTotals::default();
        for (i, &p) in Phase::ALL.iter().enumerate() {
            let st = tr.get(p);
            t.time[i] = st.time;
            t.flops[i] = st.flops;
        }
        t
    }

    pub fn time(&self, phase: Phase) -> f64 {
        self.time[idx(phase)]
    }

    pub fn flops(&self, phase: Phase) -> u64 {
        self.flops[idx(phase)]
    }

    pub fn total_time(&self) -> f64 {
        self.time.iter().sum()
    }

    /// Two-column table of nonzero phases (seconds + flops), with a
    /// totals row; `header` names the time column (e.g. "measured" or
    /// "simulated").
    pub fn render_table(&self, header: &str) -> String {
        let mut out = format!("{:<14} {:>12}  {:>14}\n", "phase", header, "flops");
        for (i, &p) in Phase::ALL.iter().enumerate() {
            if self.time[i] == 0.0 && self.flops[i] == 0 {
                continue;
            }
            out.push_str(&format!(
                "{:<14} {:>12} {:>14}\n",
                p.label(),
                crate::metrics::fmt_secs(self.time[i]),
                crate::metrics::fmt_count(self.flops[i]),
            ));
        }
        out.push_str(&format!(
            "{:<14} {:>12} {:>14}\n",
            "total",
            crate::metrics::fmt_secs(self.total_time()),
            crate::metrics::fmt_count(self.flops.iter().sum::<u64>()),
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(name: &'static str, phase: Option<Phase>, start: u64, dur: u64, flops: u64) -> SpanRecord {
        SpanRecord { trace: 1, name, phase, start_ns: start, dur_ns: dur, tid: 1, depth: 0, flops }
    }

    #[test]
    fn chrome_trace_shape() {
        let spans = vec![
            rec("http_request", None, 1_000, 5_000_000, 0),
            rec("Corr", Some(Phase::Corr), 2_000, 1_000_000, 1234),
            rec("gram_panel_hit", None, 3_000, 0, 0),
        ];
        let json = chrome_trace_json(&spans);
        assert!(json.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["));
        assert!(json.ends_with("]}"));
        assert!(json.contains("\"name\":\"http_request\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"cat\":\"Corr\""));
        assert!(json.contains("\"flops\":1234"));
        // Zero-duration unphased records render as instant markers.
        assert!(json.contains("\"ph\":\"i\""));
        // Balanced braces/brackets as a cheap well-formedness check.
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn span_tree_indents_by_depth() {
        let mut inner = rec("Corr", Some(Phase::Corr), 2_000, 1_000, 64);
        inner.depth = 1;
        let spans = vec![rec("fit", None, 1_000, 10_000, 0), inner];
        let tree = span_tree(&spans);
        assert!(tree.contains("thread 1\n"));
        assert!(tree.contains("  fit"));
        assert!(tree.contains("    Corr"));
        assert!(tree.contains("64 flops"));
    }

    #[test]
    fn phase_totals_match_between_spans_and_tracer() {
        let spans = vec![
            rec("Corr", Some(Phase::Corr), 0, 2_000_000_000, 100),
            rec("Corr", Some(Phase::Corr), 0, 1_000_000_000, 50),
            rec("Cholesky", Some(Phase::Cholesky), 0, 500_000_000, 10),
        ];
        let measured = PhaseTotals::from_spans(&spans);
        assert!((measured.time(Phase::Corr) - 3.0).abs() < 1e-9);
        assert_eq!(measured.flops(Phase::Corr), 150);

        let mut tr = Tracer::new();
        tr.add_time(Phase::Corr, 3.0);
        tr.add_flops(Phase::Corr, 150);
        tr.add_time(Phase::Cholesky, 0.5);
        tr.add_flops(Phase::Cholesky, 10);
        let sim = PhaseTotals::from_tracer(&tr);
        for p in Phase::ALL {
            assert!((measured.time(p) - sim.time(p)).abs() < 1e-9);
            assert_eq!(measured.flops(p), sim.flops(p));
        }
        let table = measured.render_table("measured");
        assert!(table.contains("Corr"));
        assert!(table.contains("total"));
        assert!(!table.contains("Bcast"));
    }
}
