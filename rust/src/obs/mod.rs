//! Observability: end-to-end tracing spans, typed metrics, and trace
//! export for the real serving stack (see DESIGN.md §"Observability").
//!
//! The paper's argument is a per-phase cost model; the SimCluster
//! [`crate::cluster::tracer::Tracer`] attributes *simulated* time to
//! the [`Phase`] taxonomy, and this module measures the *same phases
//! on real hardware* so the two are directly comparable:
//!
//! * [`span`]/[`phase_span`]/[`instant`] — RAII spans on a
//!   thread-local stack, buffered per worker and drained into the
//!   bounded global [`TraceSink`] ([`sink`]). Per-request `trace_id`s
//!   are minted by the HTTP front end ([`next_trace_id`]), carried
//!   through [`crate::serve::FitJob`], and echoed in every JSON
//!   response; `GET /trace/<id>` replays one request as
//!   chrome://tracing JSON ([`chrome_trace_json`]).
//! * [`metrics`] — counters / gauges / log-bucket histograms behind
//!   `GET /metrics` (Prometheus text) and the `/stats` JSON view.
//! * [`export`] — chrome-trace rendering, the `calars trace` span
//!   tree, and [`PhaseTotals`] for measured-vs-simulated tables.
//!
//! Contract: tracing is **passive** — it reads clocks and increments
//! counters but never feeds back into any numeric path, so fits are
//! bit-identical with tracing on or off (property-tested in
//! `tests/obs.rs`), and with `CALARS_TRACE=off` every probe reduces to
//! one relaxed atomic load.

pub mod export;
pub mod metrics;
pub mod span;

pub use crate::cluster::tracer::{Category, Phase};
pub use export::{chrome_trace_json, span_tree, PhaseTotals};
pub use metrics::{global, latency_bounds, Counter, Gauge, Histogram, Registry};
pub use span::{
    current_trace, enabled, flush_thread, format_trace_id, install_trace, instant, next_trace_id,
    now_ns, parse_trace_id, phase_span, record_span_ending_now, set_enabled, sink, span,
    uninstall_trace, with_trace, SinkStats, SlowEntry, SpanGuard, SpanRecord, TraceSink,
};
