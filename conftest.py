"""Repo-root pytest config: make `compile.*` importable when running
`pytest python/tests/` from the repository root (the Makefile's
`make test` cds into python/ instead; both invocations work)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
