//! A hand-rolled Rust scanner: good enough to separate *code* from
//! *comments* and to blank out string/char literal contents, which is
//! all the rule engine needs to match patterns without false positives
//! from prose ("don't call `.unwrap()`" in a doc comment must not
//! fire a panic-safety rule).
//!
//! The scanner is line-oriented: for every source line it produces the
//! code text (string/char literal contents replaced by spaces,
//! comments removed, byte positions preserved for ASCII) and the
//! comment text (everything inside `//`/`///`/`//!` and `/* */`,
//! where the `SAFETY:` and `audit: allow(..)` markers live). A second
//! pass brace-matches `#[cfg(test)]` items so rules can exempt test
//! code.
//!
//! Handled: nested block comments, escaped string characters, raw
//! strings (`r"…"`, `r#"…"#`, any hash depth), byte strings/chars, and
//! the char-literal vs. lifetime ambiguity (`'a'` vs. `&'a str`).
//! Non-ASCII bytes are blanked to spaces — every pattern the rules
//! match is pure ASCII, and blanking keeps line/column arithmetic
//! trivial.

/// One scanned source line.
#[derive(Debug, Default, Clone)]
pub struct LineScan {
    /// Code with comments stripped and literal contents blanked.
    pub code: String,
    /// Comment text on this line (line comments and the slice of any
    /// block comment crossing it), without the `//`/`/*` markers.
    pub comment: String,
}

/// One string literal's *contents*, captured out-of-band while the
/// code stream gets blanked. The contract rules (ERR-MAP) need the
/// actual route and metric-name strings the code ships, which the
/// blanking deliberately erases from [`LineScan::code`].
#[derive(Debug, Clone)]
pub struct StrLit {
    /// 1-based line the literal *opens* on.
    pub line: usize,
    /// Raw contents between the delimiters (escapes unprocessed).
    pub text: String,
}

/// A fully scanned file.
#[derive(Debug, Default)]
pub struct FileScan {
    pub lines: Vec<LineScan>,
    /// `in_test[i]` — line `i` (0-based) sits inside a `#[cfg(test)]`
    /// item (attribute line through closing brace, inclusive).
    pub in_test: Vec<bool>,
    /// Every string literal in source order (see [`StrLit`]).
    pub strs: Vec<StrLit>,
}

impl FileScan {
    /// The blanked code joined with `\n` — the text rules match on.
    pub fn code_text(&self) -> String {
        let mut out = String::new();
        for (i, l) in self.lines.iter().enumerate() {
            if i > 0 {
                out.push('\n');
            }
            out.push_str(&l.code);
        }
        out
    }

    /// 1-based line number of byte offset `off` in [`Self::code_text`].
    pub fn line_of_offset(&self, text: &str, off: usize) -> usize {
        text.as_bytes()[..off].iter().filter(|&&b| b == b'\n').count() + 1
    }

    /// True when 1-based `line` is inside a `#[cfg(test)]` item.
    pub fn is_test_line(&self, line: usize) -> bool {
        self.in_test.get(line.saturating_sub(1)).copied().unwrap_or(false)
    }
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Normal,
    /// Inside a `"…"` string (escape-aware, may span lines).
    Str,
    /// Inside `r##"…"##` with the given hash count.
    RawStr(usize),
    /// Inside `/* … */` at the given nesting depth.
    Block(usize),
    /// Inside `// …` until end of line.
    Line,
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Blank a byte into the code stream: ASCII passes through, anything
/// else becomes a space (see module docs).
fn code_push(code: &mut String, b: u8) {
    code.push(if b.is_ascii() { b as char } else { ' ' });
}

fn comment_push(comment: &mut String, b: u8) {
    comment.push(if b.is_ascii() { b as char } else { ' ' });
}

/// Scan `src` into per-line code/comment streams plus test-region
/// marking.
pub fn scan(src: &str) -> FileScan {
    let bytes = src.as_bytes();
    let n = bytes.len();
    let mut lines: Vec<LineScan> = Vec::new();
    let mut cur = LineScan::default();
    let mut strs: Vec<StrLit> = Vec::new();
    let mut lit = StrLit { line: 0, text: String::new() }; // in-flight literal
    let mut mode = Mode::Normal;
    let mut i = 0;
    let mut prev_code: u8 = 0; // last byte pushed to code (ident check)

    while i < n {
        let b = bytes[i];
        if b == b'\n' {
            lines.push(std::mem::take(&mut cur));
            if mode == Mode::Line {
                mode = Mode::Normal;
            }
            if matches!(mode, Mode::Str | Mode::RawStr(_)) {
                lit.text.push('\n');
            }
            i += 1;
            continue;
        }
        match mode {
            Mode::Line => {
                comment_push(&mut cur.comment, b);
                i += 1;
            }
            Mode::Block(depth) => {
                if b == b'/' && bytes.get(i + 1) == Some(&b'*') {
                    mode = Mode::Block(depth + 1);
                    i += 2;
                } else if b == b'*' && bytes.get(i + 1) == Some(&b'/') {
                    mode = if depth == 1 { Mode::Normal } else { Mode::Block(depth - 1) };
                    i += 2;
                } else {
                    comment_push(&mut cur.comment, b);
                    i += 1;
                }
            }
            Mode::Str => {
                if b == b'\\' {
                    cur.code.push(' ');
                    lit.text.push('\\');
                    if i + 1 < n && bytes[i + 1] != b'\n' {
                        cur.code.push(' ');
                        lit.text.push(if bytes[i + 1].is_ascii() { bytes[i + 1] as char } else { ' ' });
                        i += 2;
                    } else {
                        i += 1;
                    }
                } else if b == b'"' {
                    cur.code.push('"');
                    prev_code = b'"';
                    mode = Mode::Normal;
                    strs.push(std::mem::replace(&mut lit, StrLit { line: 0, text: String::new() }));
                    i += 1;
                } else {
                    cur.code.push(' ');
                    lit.text.push(if b.is_ascii() { b as char } else { ' ' });
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if b == b'"' && bytes[i + 1..].len() >= hashes
                    && bytes[i + 1..i + 1 + hashes].iter().all(|&h| h == b'#')
                {
                    for _ in 0..=hashes {
                        cur.code.push(' ');
                    }
                    prev_code = b'"';
                    mode = Mode::Normal;
                    strs.push(std::mem::replace(&mut lit, StrLit { line: 0, text: String::new() }));
                    i += 1 + hashes;
                } else {
                    cur.code.push(' ');
                    lit.text.push(if b.is_ascii() { b as char } else { ' ' });
                    i += 1;
                }
            }
            Mode::Normal => {
                if b == b'/' && bytes.get(i + 1) == Some(&b'/') {
                    mode = Mode::Line;
                    i += 2;
                    // Skip the doc-comment marker so `///x` and `//!x`
                    // yield comment text `x`.
                    if i < n && (bytes[i] == b'/' || bytes[i] == b'!') {
                        i += 1;
                    }
                } else if b == b'/' && bytes.get(i + 1) == Some(&b'*') {
                    mode = Mode::Block(1);
                    i += 2;
                } else if b == b'"' {
                    cur.code.push('"');
                    mode = Mode::Str;
                    lit = StrLit { line: lines.len() + 1, text: String::new() };
                    i += 1;
                } else if (b == b'r' || b == b'b') && !is_ident(prev_code) && raw_str_at(bytes, i).is_some()
                {
                    let (hashes, consumed) = raw_str_at(bytes, i).unwrap();
                    for _ in 0..consumed {
                        cur.code.push(' ');
                    }
                    mode = Mode::RawStr(hashes);
                    lit = StrLit { line: lines.len() + 1, text: String::new() };
                    i += consumed;
                } else if b == b'b' && bytes.get(i + 1) == Some(&b'"') && !is_ident(prev_code) {
                    cur.code.push('b');
                    prev_code = b'b';
                    i += 1; // the quote is handled on the next iteration
                } else if b == b'\'' {
                    i = scan_quote(bytes, i, &mut cur.code);
                    prev_code = b'\'';
                } else {
                    code_push(&mut cur.code, b);
                    prev_code = if b.is_ascii() { b } else { b' ' };
                    i += 1;
                }
            }
        }
    }
    lines.push(cur);

    let in_test = mark_test_regions(&lines);
    FileScan { lines, in_test, strs }
}

/// If a raw (byte) string literal starts at `i` (`r"`, `r#"`, `br"`,
/// `br##"`, …), return (hash count, bytes consumed through the opening
/// quote).
fn raw_str_at(bytes: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if bytes.get(j) == Some(&b'b') {
        j += 1;
    }
    if bytes.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while bytes.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if bytes.get(j) == Some(&b'"') {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

/// Scan a `'` at position `i`: either a char literal (blank its
/// contents) or a lifetime (pass through). Returns the next position.
fn scan_quote(bytes: &[u8], i: usize, code: &mut String) -> usize {
    let n = bytes.len();
    // Escaped char literal: '\n', '\'', '\\', '\u{…}' …
    if bytes.get(i + 1) == Some(&b'\\') {
        code.push('\''); // opening quote
        code.push(' '); // the backslash
        let mut j = i + 2;
        // The escaped character itself is consumed unconditionally: it
        // may be a quote ('\'') or a backslash ('\\'), neither of which
        // may close the literal or re-enter escape handling — getting
        // this wrong used to let '\\' swallow the closing quote and
        // blank real code up to the next stray quote.
        if j < n && bytes[j] != b'\n' {
            code.push(' ');
            j += 1;
        }
        // Remainder of multi-char escapes: '\u{1F600}', '\x7f'.
        while j < n && bytes[j] != b'\'' && bytes[j] != b'\n' {
            code.push(' ');
            j += 1;
        }
        if j < n && bytes[j] == b'\'' {
            code.push('\'');
            return j + 1;
        }
        return j;
    }
    // Plain char literal 'x' (x may be multi-byte — find the closing
    // quote within a few bytes).
    if bytes.get(i + 1).is_some() && bytes.get(i + 1) != Some(&b'\'') {
        for j in i + 2..(i + 6).min(n) {
            if bytes[j] == b'\'' {
                // Lifetime-vs-char disambiguation: 'a' is a char
                // literal, 'a: or 'a, or 'a> are lifetimes. A closing
                // quote directly after one scalar means char literal —
                // unless the "contents" continue as an identifier
                // ('static' never occurs: too long for this window).
                if j == i + 2 && is_ident(bytes[i + 1]) && j + 1 < n && is_ident(bytes[j + 1]) {
                    break; // e.g. `'a'b` — not a char literal; treat as lifetime
                }
                code.push('\'');
                for _ in i + 1..j {
                    code.push(' ');
                }
                code.push('\'');
                return j + 1;
            }
            if !bytes[j].is_ascii() {
                continue; // inside a multi-byte scalar
            }
            if j == i + 2 && !is_ident(bytes[j]) {
                break; // 'x) or 'x, — lifetime-like, stop looking
            }
        }
    }
    // Lifetime (or stray quote): emit it and move on.
    code.push('\'');
    i + 1
}

/// Mark the line span of every `#[cfg(test)]` item by brace matching
/// over the blanked code.
fn mark_test_regions(lines: &[LineScan]) -> Vec<bool> {
    let mut in_test = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        let squashed: String =
            lines[i].code.chars().filter(|c| !c.is_whitespace()).collect();
        if !squashed.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        // Attribute found: everything until the item's closing brace
        // is test code.
        let start = i;
        let mut depth: i64 = 0;
        let mut opened = false;
        let mut j = i;
        while j < lines.len() {
            for c in lines[j].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                break;
            }
            j += 1;
        }
        let end = j.min(lines.len() - 1);
        for t in in_test.iter_mut().take(end + 1).skip(start) {
            *t = true;
        }
        i = end + 1;
    }
    in_test
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_separated() {
        let s = scan("let x = \"call .unwrap() here\"; // but .expect( in prose\n");
        assert!(!s.lines[0].code.contains("unwrap"));
        assert!(s.lines[0].comment.contains(".expect("));
        assert!(s.lines[0].code.contains("let x ="));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let s = scan("let p = r#\"panic!(\"x\")\"#;\nlet q = 1;\n");
        assert!(!s.code_text().contains("panic!"));
        assert!(s.lines[1].code.contains("let q = 1;"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let s = scan("a /* one /* two */ still */ b\n/* open\n.unwrap()\n*/ c\n");
        assert!(s.lines[0].code.contains('a') && s.lines[0].code.contains('b'));
        assert!(!s.code_text().contains("unwrap"));
        assert!(s.lines[2].comment.contains(".unwrap()"));
        assert!(s.lines[3].code.contains('c'));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let s = scan("fn f<'a>(x: &'a str) -> char { '\\'' }\nlet c = 'y';\nlet d = b'\"';\n");
        let t = s.code_text();
        assert!(t.contains("<'a>"), "lifetime kept: {t}");
        assert!(t.contains("&'a str"));
        assert!(!t.contains('y'), "char literal contents blanked: {t}");
        // The quote inside b'"' must not open a string.
        assert!(s.lines[2].code.contains("let d ="));
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn prod2() {}\n";
        let s = scan(src);
        assert!(!s.is_test_line(1));
        assert!(s.is_test_line(2));
        assert!(s.is_test_line(4));
        assert!(s.is_test_line(5));
        assert!(!s.is_test_line(6));
    }

    #[test]
    fn escaped_backslash_char_literal_does_not_swallow_code() {
        // Regression: '\\' used to step PAST its closing quote, leaving
        // the scanner blanking real code until the next stray quote —
        // which silently masked any rule hit on the same line.
        let s = scan("let sep = '\\\\'; let x = v.unwrap();\n");
        assert!(s.lines[0].code.contains(".unwrap()"), "{:?}", s.lines[0].code);
        // Byte positions preserved: blanked line length == source length.
        assert_eq!(s.lines[0].code.len(), "let sep = '\\\\'; let x = v.unwrap();".len());
    }

    #[test]
    fn escaped_quote_char_literal_closes_on_the_real_quote() {
        // Regression: '\'' used to treat the ESCAPED quote as the
        // closing delimiter, leaving the true closing quote in the
        // stream to confuse the next literal on the line.
        let s = scan("let q = '\\''; let y = w.expect(\"gone\");\n");
        assert!(s.lines[0].code.contains(".expect("), "{:?}", s.lines[0].code);
        assert!(!s.lines[0].code.contains("gone"), "string contents blanked: {:?}", s.lines[0].code);
        assert_eq!(s.lines[0].code.len(), "let q = '\\''; let y = w.expect(\"gone\");".len());
    }

    #[test]
    fn raw_string_hash_depths_preserve_positions() {
        // The rule hit after the raw string must land on the right
        // byte offset (same line, same column arithmetic).
        let src = "let p = r##\"has \"# inside\"##; v.unwrap();\n";
        let s = scan(src);
        assert!(s.lines[0].code.contains(".unwrap()"), "{:?}", s.lines[0].code);
        assert!(!s.lines[0].code.contains("inside"));
        assert_eq!(s.lines[0].code.len(), src.len() - 1);
        assert_eq!(s.strs.len(), 1);
        assert_eq!(s.strs[0].text, "has \"# inside");
    }

    #[test]
    fn deeply_nested_block_comments_unwind_fully() {
        let s = scan("/* a /* b /* c */ d */ e */ f.unwrap();\n");
        assert!(s.lines[0].code.contains("f.unwrap();"), "{:?}", s.lines[0].code);
        assert!(s.lines[0].comment.contains('c'));
    }

    #[test]
    fn lifetime_then_char_literal_on_one_line() {
        // 'a> (lifetime) followed by a real char literal: the lifetime
        // quote must not open a literal that eats the rest of the line.
        let s = scan("fn f<'a>(x: &'a [u8]) -> bool { x[0] == b'x' && x.len() > '0' as usize }\n");
        let t = s.code_text();
        assert!(t.contains("<'a>"), "{t}");
        assert!(t.contains("x.len()"), "{t}");
        assert!(!t.contains("b'x'"), "char contents blanked: {t}");
    }

    #[test]
    fn string_literal_contents_are_captured_with_lines() {
        let src = "fn f() {\n    let r = \"/fit\";\n    let m = \"calars_x_total\";\n    let raw = r#\"multi\nline\"#;\n}\n";
        let s = scan(src);
        let got: Vec<(usize, &str)> =
            s.strs.iter().map(|l| (l.line, l.text.as_str())).collect();
        assert_eq!(
            got,
            vec![(2, "/fit"), (3, "calars_x_total"), (4, "multi\nline")],
            "{:?}",
            s.strs
        );
    }

    #[test]
    fn multiline_string_stays_blanked() {
        let s = scan("let s = \"line one\n.unwrap()\nend\";\nlet t = 2;\n");
        assert!(!s.code_text().contains("unwrap"));
        assert!(s.lines[3].code.contains("let t = 2;"));
    }
}
