//! The rule engine: every project invariant as a machine-checked rule
//! over [`crate::lexer::FileScan`]s.
//!
//! Rules are scoped by path (a panic in a test is fine; a panic in a
//! serve request path is not) and report `file:line` findings with
//! stable rule ids. Scoped exceptions are granted by allow markers in
//! comments:
//!
//! ```text
//! // audit: allow(RULE-ID) -- reason            (this line + the next)
//! // audit: allow(RULE-ID, file) -- reason      (whole file)
//! ```
//!
//! A marker without a ` -- reason` is itself a finding (ALLOW-REASON),
//! and a marker that suppresses nothing is a warning (ALLOW-UNUSED) —
//! so the exception list can only shrink, never rot.

use crate::lexer::FileScan;

/// Severity of a finding. Warnings exit 0 unless `--deny-warnings`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

/// One diagnostic.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Repo-relative path (forward slashes).
    pub path: String,
    /// 1-based line.
    pub line: usize,
    pub rule: &'static str,
    pub severity: Severity,
    pub message: String,
}

/// Static rule metadata: id, one-line summary, and the `--explain`
/// documentation of the invariant it enforces.
pub struct RuleDoc {
    pub id: &'static str,
    pub summary: &'static str,
    pub explain: &'static str,
}

/// Every rule the engine knows, in report order.
pub const RULES: &[RuleDoc] = &[
    RuleDoc {
        id: "DET-CMP",
        summary: "no partial_cmp(..).unwrap() — use total_cmp",
        explain: "Determinism / NaN totality.  `a.partial_cmp(&b).unwrap()` panics the\n\
                  moment a NaN reaches the comparison — exactly the degenerate inputs\n\
                  the mLARS tournament hardening (PR 5) exists for.  `f64::total_cmp`\n\
                  is a total order (IEEE 754 totalOrder): NaN sorts deterministically\n\
                  instead of aborting the fit, so every max_by/sort_by over\n\
                  correlations, scores or latencies stays panic-free and\n\
                  reproducible bit-for-bit.  Scope: all audited code, including\n\
                  tests and benches (a panicking comparator in a test helper hides\n\
                  the regression it should catch).",
    },
    RuleDoc {
        id: "DET-MAP",
        summary: "no unordered HashMap/HashSet iteration in hot-path modules",
        explain: "Determinism / iteration order.  HashMap and HashSet iteration order\n\
                  is randomized per process; iterating one inside fit/kern/lars/\n\
                  batch/select can silently reorder floating-point combines and\n\
                  break the bit-identity contract across CALARS_THREADS (and across\n\
                  runs).  Keyed *lookup* is fine — the rule fires on `.iter()`,\n\
                  `.keys()`, `.values()`, `.into_iter()`, `.drain()`, `.retain()`\n\
                  and `for … in` over a hash container declared in the same file.\n\
                  If the iteration is genuinely order-insensitive, or the results\n\
                  are sorted before use, mark the site:\n\
                  `// audit: allow(DET-MAP) -- sorted before use`.\n\
                  Scope: rust/src/{fit,kern,lars,batch,select}, non-test code.",
    },
    RuleDoc {
        id: "DET-TIME",
        summary: "no wall-clock reads or RNG construction in fitter cores",
        explain: "Determinism / hidden inputs.  The fitter cores (rust/src/lars,\n\
                  rust/src/baselines, rust/src/batch) must be pure functions of\n\
                  (matrix, response, spec): an Instant::now() or RNG constructed\n\
                  inside a core is a hidden input that can leak into control flow\n\
                  (adaptive cutoffs, sampled work) and desynchronize the one\n\
                  canonical summation order.  Timing belongs at the calars::fit\n\
                  boundary (FitResult.wall_secs) or behind the observability layer;\n\
                  randomness must come in through the spec's seeds.  Sites whose\n\
                  clock reads feed *only* phase timings (never numerics) carry a\n\
                  file-scope allow with that argument.  Scope: fitter-core modules,\n\
                  non-test code.",
    },
    RuleDoc {
        id: "DET-SUM",
        summary: "no ad-hoc f64 reductions outside calars::kern",
        explain: "Determinism / one canonical summation order.  Floating-point\n\
                  addition does not associate; the whole point of calars::kern is\n\
                  that every additive reduction in the model-numerics path runs in\n\
                  ONE canonical order (4-accumulator pairwise kernels + fixed par\n\
                  chunk combines), so refactors cannot silently reorder a sum and\n\
                  change served bits.  An ad-hoc `.sum::<f64>()` or additive\n\
                  `fold(0.0, …)` outside kern/kern::reference creates a second,\n\
                  unaudited order.  Max/min folds are order-insensitive and exempt.\n\
                  Fix by calling a kern kernel, or allow-mark with an argument for\n\
                  why the order is fixed (e.g. a serial combine over per-rank\n\
                  partials in rank order).  Scope: rust/src/{lars,linalg,batch,fit,\n\
                  select,baselines,cluster,data}, non-test code.",
    },
    RuleDoc {
        id: "PANIC-UNWRAP",
        summary: "no unwrap/expect/panic in serve request paths",
        explain: "Panic safety.  A panic inside a serve request path kills a worker\n\
                  mid-request; before PR 5's hardening one poisoned lock then\n\
                  cascaded into a server-wide abort.  Request-path code must return\n\
                  typed errors (crate::error::ErrorKind — the HTTP layer maps\n\
                  InvalidSpec→400, RankDeficient→422, Internal→500) instead of\n\
                  calling .unwrap()/.expect()/panic!/unreachable!/todo!.\n\
                  Startup-time spawns (before the server accepts traffic) may\n\
                  allow-mark with that reason.  Scope: rust/src/serve (minus the\n\
                  load-generator client loadgen.rs), non-test code;\n\
                  `.lock().unwrap()` is reported by PANIC-LOCK instead.",
    },
    RuleDoc {
        id: "PANIC-LOCK",
        summary: "every .lock() must recover from poisoning, not unwrap",
        explain: "Panic safety / lock discipline.  `mutex.lock().unwrap()` converts\n\
                  one panicking thread into a poison-panic in every OTHER thread\n\
                  that touches the mutex — the exact cascade PR 5 removed from the\n\
                  serve layer.  Every guard under calars's plain-data locking\n\
                  discipline is recoverable, so lock acquisition must use the\n\
                  recovery idiom\n\
                  `.lock().unwrap_or_else(std::sync::PoisonError::into_inner)`\n\
                  (or an explicit match on the PoisonError).  The nightly\n\
                  ThreadSanitizer CI job dynamically backs this static rule.\n\
                  Scope: all rust/src non-test code.",
    },
    RuleDoc {
        id: "UNSAFE-SCOPE",
        summary: "unsafe code is permitted only in rust/src/par and rust/src/kern/simd",
        explain: "Unsafe budget.  The crate's entire unsafe surface is two audited\n\
                  regions: the lifetime-erasure sites in the thread pool\n\
                  (rust/src/par), where the fork-join structure makes borrowed\n\
                  closures sound, and the SIMD kernel backends\n\
                  (rust/src/kern/simd), where `#[target_feature]` functions are\n\
                  unsafe-to-call by construction and every call site is guarded by\n\
                  the runtime ISA detection in KernBackend::supported() (see\n\
                  DESIGN.md §Static analysis — the aliasing/lifetime and\n\
                  feature-detection arguments).  `unsafe` anywhere else is a\n\
                  finding: new unsafe code needs a new documented budget, not a\n\
                  quiet block.  Scope: every audited file, tests and benches\n\
                  included.",
    },
    RuleDoc {
        id: "UNSAFE-DOC",
        summary: "every unsafe block needs a // SAFETY: comment",
        explain: "Unsafe budget / documentation.  Each `unsafe` block or function\n\
                  inside the permitted scope must be immediately preceded by (or\n\
                  share a line with) a `SAFETY:` comment — doc comment for unsafe\n\
                  fns, line comment for blocks; intervening attribute lines like\n\
                  `#[target_feature(...)]` are looked through — stating the\n\
                  invariant that makes it sound: the reviewer-facing half of the\n\
                  unsafe budget.  Scope: rust/src/par and rust/src/kern/simd.",
    },
    RuleDoc {
        id: "SIMD-TARGET",
        summary: "every unsafe fn in kern/simd needs #[target_feature(…)]",
        explain: "SIMD backend discipline.  Inside rust/src/kern/simd the only\n\
                  reason a function is `unsafe` is that it is compiled for an ISA\n\
                  the host may lack, so every `unsafe fn` there must carry a\n\
                  `#[target_feature(enable = …)]` attribute — that is what makes\n\
                  the intrinsics compile to the intended vector instructions AND\n\
                  what the runtime dispatch layer's KernBackend::supported() guard\n\
                  is promising about.  An unsafe fn without the attribute is\n\
                  either needlessly unsafe or silently compiled for the baseline\n\
                  target, defeating the backend.  Scope: rust/src/kern/simd.",
    },
    RuleDoc {
        id: "DEP-EXT",
        summary: "no external dependencies in any Cargo.toml",
        explain: "Zero-dependency contract.  The workspace builds offline: rng,\n\
                  argv parsing, property testing, HTTP, the on-disk model format —\n\
                  all hand-rolled in-tree.  Any [dependencies]/[dev-dependencies]/\n\
                  [build-dependencies] entry that resolves outside the workspace\n\
                  (a version, git or registry source) is a finding.  In-workspace\n\
                  `path = …` members (calars-audit itself) are allowed — they are\n\
                  part of the tree, not an external dependency.  Scope: the root\n\
                  manifest and every workspace member manifest.",
    },
    RuleDoc {
        id: "ALLOW-REASON",
        summary: "allow markers must name a known rule and carry a reason",
        explain: "Exception hygiene.  `// audit: allow(RULE) -- reason` grants a\n\
                  scoped, *reasoned* exception; the reason is the audit trail.  A\n\
                  marker with no ` -- reason`, or naming a rule id the engine does\n\
                  not know (typo-proofing), is itself an error — the tree must\n\
                  contain zero unexplained exceptions.",
    },
    RuleDoc {
        id: "ALLOW-UNUSED",
        summary: "allow markers that suppress nothing (warning)",
        explain: "Exception hygiene.  An allow marker that no longer suppresses any\n\
                  finding is dead weight — the code it excused was fixed or moved.\n\
                  Reported as a warning (an error under --deny-warnings, which CI\n\
                  uses) so stale exceptions get deleted instead of accumulating.",
    },
    RuleDoc {
        id: "PANIC-REACH",
        summary: "no panic-capable code reachable from a serve entry point",
        explain: "Panic safety, interprocedurally.  The engine parses every item,\n\
                  builds an approximate call graph, and BFS-walks it from the serve\n\
                  entry points (`route`, `handle_*`, the connection/queue worker\n\
                  loops).  Any reachable `panic!`-family macro, `.unwrap()` or\n\
                  `.expect()` outside the serve layer — plus any unchecked\n\
                  index/slice expression on the request-parsing surface\n\
                  (serve/http.rs, serve/protocol.rs), where the index comes from\n\
                  untrusted bytes — is a finding: one poisoned fit must come back\n\
                  as a typed error, not tear down a worker.  Shield a span with\n\
                  `catch_unwind(…)` or allow-mark the site with the invariant that\n\
                  rules the panic out.  The resolver over-approximates untyped\n\
                  method receivers and cannot see trait-object or fn-pointer\n\
                  dispatch (DESIGN.md §Static analysis lists the blind spots).\n\
                  Scope: non-test code under rust/src reachable from\n\
                  rust/src/serve entries.",
    },
    RuleDoc {
        id: "LOCK-ORDER",
        summary: "lock acquisition order must be cycle-free (static deadlock check)",
        explain: "Deadlock freedom.  Every Mutex/RwLock acquisition —\n\
                  `recv.lock()`/`.read()`/`.write()`, guard-returning wrappers\n\
                  like Registry::lock, and guard-returning free helpers like\n\
                  sync::lock_recover — is resolved to a stable identity\n\
                  (`Struct.field`, `static NAME`) and a conservative hold range.\n\
                  Acquiring B while holding A adds the edge A→B, including\n\
                  transitively through calls made while the guard is live; any\n\
                  cycle in the resulting order graph (A→B with B→A elsewhere, or a\n\
                  re-entrant A→A on std's non-reentrant locks) is reported with\n\
                  both acquisition sites of every edge.  Receivers the resolver\n\
                  cannot type stay anonymous rather than guessed, so a reported\n\
                  cycle is structural, not an aliasing accident.  Scope: non-test\n\
                  code under rust/src (the lock population lives in serve, par,\n\
                  obs and kern::cache).",
    },
    RuleDoc {
        id: "ERR-MAP",
        summary: "error kinds, routes and metrics must match their documented surface",
        explain: "Contract drift.  Three documented surfaces are pinned to code:\n\
                  (1) every `ErrorKind` variant in rust/src/error.rs must have an\n\
                  HTTP status mapping in serve/http.rs — an unmapped kind is a 500\n\
                  waiting to happen; (2) every route literal served from\n\
                  serve/http.rs or serve/protocol.rs must appear in docs/API.md;\n\
                  (3) every registered `calars_*` metric name must appear there\n\
                  too, because the /metrics surface is part of the API contract.\n\
                  The checks are anchored on rust/src/error.rs and docs/API.md, so\n\
                  miniature fixture trees without those anchors pass vacuously.\n\
                  Scope: rust/src non-test code plus docs/API.md.",
    },
    RuleDoc {
        id: "UNSAFE-BUDGET",
        summary: "unsafe block counts must match the checked-in ledger",
        explain: "Unsafe budget, enforced as a ratchet.  tools/audit/unsafe.ledger\n\
                  records `path count` for every file in the sanctioned unsafe\n\
                  regions (rust/src/par, rust/src/kern/simd).  A count above the\n\
                  ledger fails the audit at the first over-budget `unsafe` keyword\n\
                  — growth is only possible by deliberately regenerating the\n\
                  ledger with --update-unsafe-ledger in the same change, which\n\
                  makes every new unsafe block a reviewed, diffed event.  A count\n\
                  below the ledger (or a stale entry) is a warning prompting a\n\
                  regenerate, so the recorded budget only ever tracks reality\n\
                  downward automatically and upward deliberately.",
    },
];

/// Look up a rule id (exact match).
pub fn rule_doc(id: &str) -> Option<&'static RuleDoc> {
    RULES.iter().find(|r| r.id == id)
}

/// What the engine knows about one file before matching: its scan and
/// its repo-relative path classification.
pub struct FileCtx<'a> {
    pub path: &'a str,
    pub scan: &'a FileScan,
}

impl FileCtx<'_> {
    fn under(&self, prefix: &str) -> bool {
        self.path.starts_with(prefix)
    }

    /// Hot-path modules for DET-MAP.
    fn is_hot_module(&self) -> bool {
        ["rust/src/fit/", "rust/src/kern/", "rust/src/lars/", "rust/src/batch/", "rust/src/select/"]
            .iter()
            .any(|p| self.under(p))
    }

    /// Fitter-core modules for DET-TIME.
    fn is_fitter_core(&self) -> bool {
        ["rust/src/lars/", "rust/src/baselines/", "rust/src/batch/"]
            .iter()
            .any(|p| self.under(p))
    }

    /// Model-numerics modules for DET-SUM (kern is the canonical home
    /// and therefore exempt).
    fn is_numerics_module(&self) -> bool {
        [
            "rust/src/lars/",
            "rust/src/linalg/",
            "rust/src/batch/",
            "rust/src/fit/",
            "rust/src/select/",
            "rust/src/baselines/",
            "rust/src/cluster/",
            "rust/src/data/",
        ]
        .iter()
        .any(|p| self.under(p))
    }

    /// Serve request-path files for PANIC-UNWRAP (loadgen is the
    /// bench *client*, not a request path).
    fn is_serve_request_path(&self) -> bool {
        self.under("rust/src/serve/") && !self.path.ends_with("loadgen.rs")
    }

    fn is_par(&self) -> bool {
        self.under("rust/src/par/")
    }

    /// The SIMD kernel backends — the second region of the unsafe
    /// budget (UNSAFE-SCOPE) and the scope of SIMD-TARGET.
    fn is_simd(&self) -> bool {
        self.under("rust/src/kern/simd/")
    }

    fn is_src(&self) -> bool {
        self.under("rust/src/")
    }
}

/// Run every source rule on one scanned file.
pub fn check_file(ctx: &FileCtx<'_>, out: &mut Vec<Finding>) {
    let text = ctx.scan.code_text();
    det_cmp(ctx, &text, out);
    if ctx.is_hot_module() {
        det_map(ctx, &text, out);
    }
    if ctx.is_fitter_core() {
        det_time(ctx, &text, out);
    }
    if ctx.is_numerics_module() {
        det_sum(ctx, &text, out);
    }
    if ctx.is_serve_request_path() {
        panic_unwrap(ctx, &text, out);
    }
    if ctx.is_src() {
        panic_lock(ctx, &text, out);
    }
    unsafe_rules(ctx, &text, out);
}

fn finding(ctx: &FileCtx<'_>, line: usize, rule: &'static str, message: String) -> Finding {
    let severity =
        if rule == "ALLOW-UNUSED" { Severity::Warning } else { Severity::Error };
    Finding { path: ctx.path.to_string(), line, rule, severity, message }
}

/// Is `text[i..]` preceded by an identifier character?
pub(crate) fn ident_before(text: &str, i: usize) -> bool {
    i > 0 && {
        let b = text.as_bytes()[i - 1];
        b.is_ascii_alphanumeric() || b == b'_'
    }
}

/// Is the byte right after `end` an identifier character?
pub(crate) fn ident_after(text: &str, end: usize) -> bool {
    text.as_bytes().get(end).is_some_and(|b| b.is_ascii_alphanumeric() || *b == b'_')
}

/// Offset of every word-boundary occurrence of `needle`.
pub(crate) fn word_occurrences(text: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(rel) = text[from..].find(needle) {
        let i = from + rel;
        if !ident_before(text, i) && !ident_after(text, i + needle.len()) {
            out.push(i);
        }
        from = i + needle.len();
    }
    out
}

/// Given the offset of an opening `(`, return the offset just past its
/// matching `)` (None if unbalanced).
pub(crate) fn match_paren(text: &str, open: usize) -> Option<usize> {
    let bytes = text.as_bytes();
    let mut depth = 0usize;
    for (k, &b) in bytes.iter().enumerate().skip(open) {
        match b {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(k + 1);
                }
            }
            _ => {}
        }
    }
    None
}

pub(crate) fn skip_ws(text: &str, mut i: usize) -> usize {
    let bytes = text.as_bytes();
    while i < bytes.len() && bytes[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

/// After offset `i`, does `.unwrap()`/`.expect(` follow (whitespace
/// allowed before the dot)?  Returns the matched suffix name.
fn panicky_suffix(text: &str, i: usize) -> Option<&'static str> {
    let j = skip_ws(text, i);
    for (pat, name) in [(".unwrap", "unwrap"), (".expect", "expect")] {
        if text[j..].starts_with(pat) {
            let end = j + pat.len();
            if !ident_after(text, end) && text.as_bytes().get(skip_ws(text, end)) == Some(&b'(') {
                return Some(name);
            }
        }
    }
    None
}

// ── DET-CMP ──────────────────────────────────────────────────────────

fn det_cmp(ctx: &FileCtx<'_>, text: &str, out: &mut Vec<Finding>) {
    for i in word_occurrences(text, "partial_cmp") {
        let open = skip_ws(text, i + "partial_cmp".len());
        if text.as_bytes().get(open) != Some(&b'(') {
            continue;
        }
        let Some(close) = match_paren(text, open) else { continue };
        if let Some(sfx) = panicky_suffix(text, close) {
            if sfx == "unwrap" {
                let line = ctx.scan.line_of_offset(text, i);
                out.push(finding(
                    ctx,
                    line,
                    "DET-CMP",
                    "partial_cmp(..).unwrap() panics on NaN; use total_cmp (or handle \
                     the None with documented NaN semantics)"
                        .to_string(),
                ));
            }
        }
    }
}

// ── DET-MAP ──────────────────────────────────────────────────────────

/// Names declared as HashMap/HashSet in this file (field or binding).
fn hash_container_names(text: &str) -> Vec<String> {
    let mut names = Vec::new();
    for ty in ["HashMap", "HashSet"] {
        for i in word_occurrences(text, ty) {
            if let Some(name) = declared_name_before(text, i) {
                if !names.contains(&name) {
                    names.push(name);
                }
            }
        }
    }
    names
}

/// For an occurrence of a type at offset `i`, walk back over `: ` or
/// `= ` to the declared identifier (`states: Mutex<HashMap<…>>` walks
/// back through the wrapper type too — good enough for lint purposes).
fn declared_name_before(text: &str, i: usize) -> Option<String> {
    let bytes = text.as_bytes();
    let mut j = i;
    // Walk back over type-ish characters to the `:` or `=` introducer.
    while j > 0 {
        let b = bytes[j - 1];
        if b.is_ascii_alphanumeric() || b == b'_' || b == b'<' || b == b'>' || b == b':'
            || b.is_ascii_whitespace() || b == b',' || b == b'(' || b == b'&'
        {
            if b == b':' && bytes.get(j.checked_sub(2)?) != Some(&b':') && !text[..j - 1].ends_with("::")
            {
                // A single `:` — the annotation introducer.
                let name_end = {
                    let mut k = j - 1;
                    while k > 0 && bytes[k - 1].is_ascii_whitespace() {
                        k -= 1;
                    }
                    k
                };
                let mut name_start = name_end;
                while name_start > 0 && {
                    let c = bytes[name_start - 1];
                    c.is_ascii_alphanumeric() || c == b'_'
                } {
                    name_start -= 1;
                }
                if name_start < name_end {
                    return Some(text[name_start..name_end].to_string());
                }
                return None;
            }
            if b == b'=' {
                // `let [mut] name = HashMap::new()`
                let mut k = j - 1;
                while k > 0 && bytes[k - 1].is_ascii_whitespace() {
                    k -= 1;
                }
                let name_end = k;
                let mut name_start = name_end;
                while name_start > 0 && {
                    let c = bytes[name_start - 1];
                    c.is_ascii_alphanumeric() || c == b'_'
                } {
                    name_start -= 1;
                }
                if name_start < name_end {
                    return Some(text[name_start..name_end].to_string());
                }
                return None;
            }
            j -= 1;
        } else {
            return None;
        }
    }
    None
}

fn det_map(ctx: &FileCtx<'_>, text: &str, out: &mut Vec<Finding>) {
    let names = hash_container_names(text);
    if names.is_empty() {
        return;
    }
    let mut seen_lines: Vec<usize> = Vec::new();
    for name in &names {
        // Method-call iteration: name.iter() / .keys() / …
        for method in [".iter()", ".keys()", ".values()", ".into_iter()", ".drain(", ".retain("] {
            let pat = format!("{name}{method}");
            let mut from = 0;
            while let Some(rel) = text[from..].find(&pat) {
                let i = from + rel;
                if !ident_before(text, i) {
                    let line = ctx.scan.line_of_offset(text, i);
                    if !seen_lines.contains(&line) {
                        seen_lines.push(line);
                        out.push(finding(
                            ctx,
                            line,
                            "DET-MAP",
                            format!(
                                "iteration over hash container `{name}` in a hot-path module: \
                                 order is randomized per process; sort before use or \
                                 allow-mark why order cannot matter"
                            ),
                        ));
                    }
                }
                from = i + pat.len();
            }
        }
        // `for … in [&[mut ]]name` on one line.
        for i in word_occurrences(text, name) {
            let before = text[..i].trim_end();
            let before = before.strip_suffix("&mut").unwrap_or(before).trim_end();
            let before = before.strip_suffix('&').unwrap_or(before).trim_end();
            if before.ends_with(" in") || before.ends_with("\nin") {
                // Only inside a for-loop header (same line has `for `).
                let line = ctx.scan.line_of_offset(text, i);
                let code = &ctx.scan.lines[line - 1].code;
                if code.contains("for ") && !seen_lines.contains(&line) {
                    seen_lines.push(line);
                    out.push(finding(
                        ctx,
                        line,
                        "DET-MAP",
                        format!(
                            "for-loop over hash container `{name}` in a hot-path module: \
                             order is randomized per process; sort before use or \
                             allow-mark why order cannot matter"
                        ),
                    ));
                }
            }
        }
    }
}

// ── DET-TIME ─────────────────────────────────────────────────────────

fn det_time(ctx: &FileCtx<'_>, text: &str, out: &mut Vec<Finding>) {
    for pat in ["Instant::now", "SystemTime::now", "Pcg64::new", "thread_rng", "from_entropy"] {
        for i in word_occurrences(text, pat) {
            let line = ctx.scan.line_of_offset(text, i);
            if ctx.scan.is_test_line(line) {
                continue;
            }
            out.push(finding(
                ctx,
                line,
                "DET-TIME",
                format!(
                    "`{pat}` inside a fitter core: cores must be pure functions of \
                     (matrix, response, spec) — time at the fit boundary, seed RNGs \
                     through the spec"
                ),
            ));
        }
    }
}

// ── DET-SUM ──────────────────────────────────────────────────────────

fn det_sum(ctx: &FileCtx<'_>, text: &str, out: &mut Vec<Finding>) {
    for i in word_occurrences(text, "sum") {
        if !text[..i].ends_with('.') || !text[i..].starts_with("sum::<f64>") {
            continue;
        }
        let line = ctx.scan.line_of_offset(text, i);
        if ctx.scan.is_test_line(line) {
            continue;
        }
        out.push(finding(
            ctx,
            line,
            "DET-SUM",
            "ad-hoc .sum::<f64>() outside calars::kern: additive reductions need \
             the one canonical summation order — call a kern kernel, or allow-mark \
             with the argument for why this order is fixed"
                .to_string(),
        ));
    }
    for i in word_occurrences(text, "fold") {
        if !text[..i].ends_with('.') {
            continue;
        }
        let open = skip_ws(text, i + "fold".len());
        if text.as_bytes().get(open) != Some(&b'(') {
            continue;
        }
        let Some(close) = match_paren(text, open) else { continue };
        let args = &text[open + 1..close - 1];
        let first = args.trim_start();
        // Only additive zero-seeded folds: max/min reductions are
        // order-insensitive, non-zero seeds are not the paper's pattern.
        if !first.starts_with("0.0") && !first.starts_with("0f64") && !first.starts_with("0_f64") {
            continue;
        }
        if args.contains("max") || args.contains("min") {
            continue;
        }
        let line = ctx.scan.line_of_offset(text, i);
        if ctx.scan.is_test_line(line) {
            continue;
        }
        out.push(finding(
            ctx,
            line,
            "DET-SUM",
            "ad-hoc additive fold(0.0, …) outside calars::kern: additive reductions \
             need the one canonical summation order — call a kern kernel, or \
             allow-mark with the argument for why this order is fixed"
                .to_string(),
        ));
    }
}

// ── PANIC-UNWRAP ─────────────────────────────────────────────────────

fn panic_unwrap(ctx: &FileCtx<'_>, text: &str, out: &mut Vec<Finding>) {
    // Macro panics.
    for pat in ["panic!", "unreachable!", "todo!", "unimplemented!"] {
        let bare = &pat[..pat.len() - 1];
        for i in word_occurrences(text, bare) {
            if !text[i + bare.len()..].starts_with('!') {
                continue;
            }
            let line = ctx.scan.line_of_offset(text, i);
            if ctx.scan.is_test_line(line) {
                continue;
            }
            out.push(finding(
                ctx,
                line,
                "PANIC-UNWRAP",
                format!("`{pat}` in a serve request path: return a typed ErrorKind instead"),
            ));
        }
    }
    // .unwrap() / .expect(…) — but `.lock().unwrap()` belongs to
    // PANIC-LOCK (one finding per defect).
    for name in ["unwrap", "expect"] {
        for i in word_occurrences(text, name) {
            if !text[..i].ends_with('.') {
                continue;
            }
            let after = skip_ws(text, i + name.len());
            if text.as_bytes().get(after) != Some(&b'(') {
                continue;
            }
            if name == "unwrap" && text.as_bytes().get(after + 1) != Some(&b')') {
                continue; // unwrap(x)? not a thing — defensive
            }
            let recv = text[..i - 1].trim_end();
            if recv.ends_with("lock()") {
                continue;
            }
            let line = ctx.scan.line_of_offset(text, i);
            if ctx.scan.is_test_line(line) {
                continue;
            }
            out.push(finding(
                ctx,
                line,
                "PANIC-UNWRAP",
                format!(
                    ".{name}() in a serve request path: return a typed ErrorKind \
                     (the HTTP layer maps kinds to 400/422/500) instead of panicking"
                ),
            ));
        }
    }
}

// ── PANIC-LOCK ───────────────────────────────────────────────────────

fn panic_lock(ctx: &FileCtx<'_>, text: &str, out: &mut Vec<Finding>) {
    for i in word_occurrences(text, "lock") {
        if !text[..i].ends_with('.') || !text[i..].starts_with("lock()") {
            continue;
        }
        let end = i + "lock()".len();
        if let Some(sfx) = panicky_suffix(text, end) {
            let line = ctx.scan.line_of_offset(text, i);
            if ctx.scan.is_test_line(line) {
                continue;
            }
            out.push(finding(
                ctx,
                line,
                "PANIC-LOCK",
                format!(
                    ".lock().{sfx}() propagates poisoning as a panic cascade; use \
                     .lock().unwrap_or_else(std::sync::PoisonError::into_inner)"
                ),
            ));
        }
    }
}

// ── UNSAFE-SCOPE / UNSAFE-DOC ────────────────────────────────────────

fn unsafe_rules(ctx: &FileCtx<'_>, text: &str, out: &mut Vec<Finding>) {
    for i in word_occurrences(text, "unsafe") {
        let line = ctx.scan.line_of_offset(text, i);
        if !ctx.is_par() && !ctx.is_simd() {
            out.push(finding(
                ctx,
                line,
                "UNSAFE-SCOPE",
                "`unsafe` outside rust/src/par and rust/src/kern/simd: the crate's \
                 unsafe budget is the thread pool's documented lifetime-erasure \
                 sites and the SIMD kernel backends only"
                    .to_string(),
            ));
            continue;
        }
        // In the SIMD backends, an `unsafe fn` must be unsafe *because*
        // it is compiled for a specific ISA — demand #[target_feature].
        if ctx.is_simd() {
            let after = skip_ws(text, i + "unsafe".len());
            if text[after..].starts_with("fn")
                && !ident_after(text, after + 2)
                && !has_target_feature(ctx.scan, line)
            {
                out.push(finding(
                    ctx,
                    line,
                    "SIMD-TARGET",
                    "`unsafe fn` in a SIMD backend without #[target_feature(…)]: \
                     every vector function must be compiled for the ISA that makes \
                     it unsafe to call"
                        .to_string(),
                ));
            }
        }
        // Inside the permitted scope: demand a SAFETY: comment on this
        // line or in the contiguous comment/attribute block above.
        if !has_safety_comment(ctx.scan, line) {
            out.push(finding(
                ctx,
                line,
                "UNSAFE-DOC",
                "`unsafe` without a `// SAFETY:` comment stating the invariant that \
                 makes it sound"
                    .to_string(),
            ));
        }
    }
}

fn has_safety_comment(scan: &FileScan, line: usize) -> bool {
    let idx = line - 1;
    if scan.lines[idx].comment.contains("SAFETY") {
        return true;
    }
    // Walk up through comment-only, blank, or attribute lines, bounded
    // (a SAFETY doc comment legitimately sits above `#[target_feature]`
    // / `#[cfg]` attributes).
    let mut k = idx;
    for _ in 0..20 {
        if k == 0 {
            break;
        }
        k -= 1;
        let l = &scan.lines[k];
        let code = l.code.trim();
        if !code.is_empty() && !code.starts_with("#[") {
            break;
        }
        if l.comment.contains("SAFETY") {
            return true;
        }
    }
    false
}

/// Does a `#[target_feature(…)]` attribute cover the fn on `line` — on
/// the line itself or among the contiguous attribute / comment / blank
/// lines directly above it?
fn has_target_feature(scan: &FileScan, line: usize) -> bool {
    let idx = line - 1;
    if scan.lines[idx].code.contains("#[target_feature(") {
        return true;
    }
    let mut k = idx;
    for _ in 0..20 {
        if k == 0 {
            break;
        }
        k -= 1;
        let code = scan.lines[k].code.trim();
        if code.is_empty() {
            continue;
        }
        if code.starts_with("#[") {
            if code.contains("#[target_feature(") {
                return true;
            }
            continue;
        }
        break;
    }
    false
}

// ── Allow markers ────────────────────────────────────────────────────

/// A parsed `audit: allow(...)` marker.
#[derive(Debug)]
pub struct AllowMarker {
    pub path: String,
    /// 1-based line the marker sits on.
    pub line: usize,
    pub rule: String,
    pub file_scope: bool,
    pub has_reason: bool,
    pub used: bool,
}

/// Extract every allow marker in a file's comments.
pub fn collect_markers(path: &str, scan: &FileScan) -> Vec<AllowMarker> {
    let mut out = Vec::new();
    for (idx, l) in scan.lines.iter().enumerate() {
        let mut from = 0;
        while let Some(rel) = l.comment[from..].find("audit: allow(") {
            let i = from + rel + "audit: allow(".len();
            let rest = &l.comment[i..];
            let Some(close) = rest.find(')') else { break };
            let inner = &rest[..close];
            let (rule, file_scope) = match inner.split_once(',') {
                Some((r, scope)) => (r.trim().to_string(), scope.trim() == "file"),
                None => (inner.trim().to_string(), false),
            };
            let after = &rest[close + 1..];
            let has_reason = after
                .trim_start()
                .strip_prefix("--")
                .is_some_and(|r| !r.trim().is_empty());
            out.push(AllowMarker {
                path: path.to_string(),
                line: idx + 1,
                rule,
                file_scope,
                has_reason,
                used: false,
            });
            from = i + close;
        }
    }
    out
}

/// Apply markers to findings: drop suppressed findings, emit
/// ALLOW-REASON errors and ALLOW-UNUSED warnings. Returns (kept
/// findings, suppressed count).
pub fn apply_markers(
    findings: Vec<Finding>,
    markers: &mut [AllowMarker],
) -> (Vec<Finding>, usize) {
    let mut kept = Vec::new();
    let mut suppressed = 0usize;
    for f in findings {
        let mut hit = false;
        for m in markers.iter_mut() {
            if m.path != f.path || m.rule != f.rule || !m.has_reason {
                continue;
            }
            if m.file_scope || m.line == f.line || m.line + 1 == f.line {
                m.used = true;
                hit = true;
            }
        }
        if hit {
            suppressed += 1;
        } else {
            kept.push(f);
        }
    }
    for m in markers.iter() {
        if rule_doc(&m.rule).is_none() {
            kept.push(Finding {
                path: m.path.clone(),
                line: m.line,
                rule: "ALLOW-REASON",
                severity: Severity::Error,
                message: format!(
                    "allow marker names unknown rule `{}` (known ids: see --list)",
                    m.rule
                ),
            });
        } else if !m.has_reason {
            kept.push(Finding {
                path: m.path.clone(),
                line: m.line,
                rule: "ALLOW-REASON",
                severity: Severity::Error,
                message: format!(
                    "allow marker for {} has no reason: write \
                     `audit: allow({}) -- <why this site is exempt>`",
                    m.rule, m.rule
                ),
            });
        } else if !m.used {
            kept.push(Finding {
                path: m.path.clone(),
                line: m.line,
                rule: "ALLOW-UNUSED",
                severity: Severity::Warning,
                message: format!(
                    "allow marker for {} suppresses nothing — delete it",
                    m.rule
                ),
            });
        }
    }
    (kept, suppressed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::scan;

    fn run_on(path: &str, src: &str) -> Vec<Finding> {
        let s = scan(src);
        let ctx = FileCtx { path, scan: &s };
        let mut out = Vec::new();
        check_file(&ctx, &mut out);
        out
    }

    #[test]
    fn det_cmp_fires_and_spares_unwrap_or() {
        let f = run_on(
            "rust/src/metrics.rs",
            "fn f(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n",
        );
        assert_eq!(f.len(), 1);
        assert_eq!((f[0].rule, f[0].line), ("DET-CMP", 2));
        let ok = run_on(
            "rust/src/metrics.rs",
            "fn f(a: f64, b: f64) { let _ = a.partial_cmp(&b).unwrap_or(std::cmp::Ordering::Equal); }\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn det_cmp_spans_lines() {
        let f = run_on(
            "benches/x.rs",
            "fn f(v: &mut Vec<f64>) {\n    v.sort_by(|a, b| a\n        .partial_cmp(b)\n        .unwrap());\n}\n",
        );
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 3, "{f:?}");
    }

    #[test]
    fn det_map_needs_declared_container_and_iteration() {
        let src = "use std::collections::HashMap;\nfn f() {\n    let mut groups: HashMap<u64, Vec<usize>> = HashMap::new();\n    for (k, v) in &groups { let _ = (k, v); }\n}\n";
        let f = run_on("rust/src/select/mod.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!((f[0].rule, f[0].line), ("DET-MAP", 4));
        // Lookup-only use is fine.
        let ok = run_on(
            "rust/src/select/mod.rs",
            "use std::collections::HashMap;\nfn f() {\n    let mut m: HashMap<u64, u64> = HashMap::new();\n    m.insert(1, 2);\n    let _ = m.get(&1);\n}\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
        // Outside hot modules the rule does not run.
        let ok2 = run_on("rust/src/serve/engine.rs", src);
        assert!(ok2.iter().all(|f| f.rule != "DET-MAP"), "{ok2:?}");
    }

    #[test]
    fn det_time_in_cores_only_and_not_in_tests() {
        let f = run_on("rust/src/lars/x.rs", "fn f() { let t = Instant::now(); }\n");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "DET-TIME");
        let ok = run_on(
            "rust/src/lars/x.rs",
            "#[cfg(test)]\nmod tests {\n    fn t() { let r = Pcg64::new(1); }\n}\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
        let ok2 = run_on("rust/src/fit/mod.rs", "fn f() { let t = Instant::now(); }\n");
        assert!(ok2.is_empty(), "timing at the fit boundary is allowed: {ok2:?}");
    }

    #[test]
    fn det_sum_flags_sums_spares_max_folds() {
        let f = run_on(
            "rust/src/lars/x.rs",
            "fn f(v: &[f64]) -> f64 { v.iter().sum::<f64>() }\n",
        );
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "DET-SUM");
        let f2 = run_on(
            "rust/src/lars/x.rs",
            "fn f(v: &[f64]) -> f64 { v.iter().fold(0.0_f64, |a, &x| a + x) }\n",
        );
        assert_eq!(f2.len(), 1, "{f2:?}");
        let ok = run_on(
            "rust/src/lars/x.rs",
            "fn f(v: &[f64]) -> f64 { v.iter().fold(0.0_f64, |a, &x| a.max(x)) }\n",
        );
        assert!(ok.is_empty(), "max-folds are order-insensitive: {ok:?}");
        let ok2 = run_on(
            "rust/src/kern/mod.rs",
            "fn f(v: &[f64]) -> f64 { v.iter().sum::<f64>() }\n",
        );
        assert!(ok2.is_empty(), "kern is the canonical home: {ok2:?}");
    }

    #[test]
    fn panic_unwrap_scope_and_lock_handoff() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let f = run_on("rust/src/serve/engine.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "PANIC-UNWRAP");
        assert!(run_on("rust/src/serve/loadgen.rs", src).is_empty());
        assert!(run_on("rust/src/lars/serial.rs", src).is_empty());
        // .lock().unwrap() is PANIC-LOCK's finding, exactly once.
        let l = run_on(
            "rust/src/serve/store.rs",
            "fn f(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap() }\n",
        );
        assert_eq!(l.len(), 1, "{l:?}");
        assert_eq!(l[0].rule, "PANIC-LOCK");
    }

    #[test]
    fn panic_lock_spares_recovery_idiom() {
        let ok = run_on(
            "rust/src/serve/store.rs",
            "fn f(m: &std::sync::Mutex<u32>) -> u32 {\n    *m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)\n}\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
        let f = run_on(
            "rust/src/obs/span.rs",
            "fn f(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().expect(\"poisoned\") }\n",
        );
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "PANIC-LOCK");
    }

    #[test]
    fn unsafe_scope_and_doc() {
        let f = run_on("rust/src/kern/mod.rs", "fn f() { unsafe { std::hint::unreachable_unchecked() } }\n");
        assert_eq!(f[0].rule, "UNSAFE-SCOPE");
        let f2 = run_on("rust/src/par/pool.rs", "fn f(p: *const u32) -> u32 { unsafe { *p } }\n");
        assert_eq!(f2.len(), 1);
        assert_eq!(f2[0].rule, "UNSAFE-DOC");
        let ok = run_on(
            "rust/src/par/pool.rs",
            "fn f(p: *const u32) -> u32 {\n    // SAFETY: caller guarantees p is live.\n    unsafe { *p }\n}\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn simd_unsafe_fn_needs_target_feature_and_safety() {
        // Bare unsafe fn in a backend: wrong on both counts.
        let f = run_on(
            "rust/src/kern/simd/avx2.rs",
            "pub unsafe fn load(p: *const f64) -> f64 {\n    *p\n}\n",
        );
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().any(|x| x.rule == "SIMD-TARGET"), "{f:?}");
        assert!(f.iter().any(|x| x.rule == "UNSAFE-DOC"), "{f:?}");
        assert!(f.iter().all(|x| x.rule != "UNSAFE-SCOPE"), "simd is in scope: {f:?}");
        // SAFETY doc above the attribute is looked through; the
        // attribute satisfies SIMD-TARGET.
        let ok = run_on(
            "rust/src/kern/simd/avx2.rs",
            "/// Lane-wise dot.\n///\n/// SAFETY: caller checked avx2 support.\n\
             #[target_feature(enable = \"avx2\")]\npub(super) unsafe fn dot() {}\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
        // An unsafe *block* in a simd file needs SAFETY but never
        // SIMD-TARGET.
        let b = run_on(
            "rust/src/kern/simd/mod.rs",
            "fn f() { unsafe { g() } }\nunsafe fn g() {}\n",
        );
        assert!(b.iter().any(|x| x.rule == "UNSAFE-DOC" && x.line == 1), "{b:?}");
        assert!(b.iter().all(|x| x.rule != "SIMD-TARGET" || x.line == 2), "{b:?}");
    }

    #[test]
    fn safety_comment_skips_attribute_lines_in_par_too() {
        let ok = run_on(
            "rust/src/par/pool.rs",
            "// SAFETY: caller guarantees p is live.\n#[inline]\nunsafe fn f(p: *const u32) -> u32 { *p }\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn comments_and_strings_never_fire() {
        let ok = run_on(
            "rust/src/serve/http.rs",
            "/// `.lock().unwrap()` sites used to cascade panics.\nfn f() { let s = \"panic!\"; let _ = s; }\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn markers_suppress_same_and_next_line() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    // audit: allow(PANIC-UNWRAP) -- startup only\n    x.unwrap()\n}\n";
        let s = scan(src);
        let ctx = FileCtx { path: "rust/src/serve/queue.rs", scan: &s };
        let mut found = Vec::new();
        check_file(&ctx, &mut found);
        let mut markers = collect_markers(ctx.path, &s);
        let (kept, suppressed) = apply_markers(found, &mut markers);
        assert_eq!(suppressed, 1);
        assert!(kept.is_empty(), "{kept:?}");
    }

    #[test]
    fn marker_without_reason_is_an_error() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    // audit: allow(PANIC-UNWRAP)\n    x.unwrap()\n}\n";
        let s = scan(src);
        let ctx = FileCtx { path: "rust/src/serve/queue.rs", scan: &s };
        let mut found = Vec::new();
        check_file(&ctx, &mut found);
        let mut markers = collect_markers(ctx.path, &s);
        let (kept, _) = apply_markers(found, &mut markers);
        assert!(kept.iter().any(|f| f.rule == "ALLOW-REASON"), "{kept:?}");
        assert!(kept.iter().any(|f| f.rule == "PANIC-UNWRAP"), "reasonless ⇒ no suppression");
    }

    #[test]
    fn unused_marker_warns_and_file_scope_works() {
        let src = "// audit: allow(DET-SUM, file) -- fixed rank-order combine\nfn f(v: &[f64]) -> f64 { v.iter().sum::<f64>() }\nfn g(v: &[f64]) -> f64 { v.iter().sum::<f64>() }\n";
        let s = scan(src);
        let ctx = FileCtx { path: "rust/src/lars/blars.rs", scan: &s };
        let mut found = Vec::new();
        check_file(&ctx, &mut found);
        let mut markers = collect_markers(ctx.path, &s);
        let (kept, suppressed) = apply_markers(found, &mut markers);
        assert_eq!(suppressed, 2, "file scope suppresses every site");
        assert!(kept.is_empty(), "{kept:?}");

        let src2 = "// audit: allow(DET-SUM) -- nothing here\nfn f() {}\n";
        let s2 = scan(src2);
        let mut markers2 = collect_markers("rust/src/lars/x.rs", &s2);
        let (kept2, _) = apply_markers(Vec::new(), &mut markers2);
        assert_eq!(kept2.len(), 1);
        assert_eq!(kept2[0].rule, "ALLOW-UNUSED");
        assert_eq!(kept2[0].severity, Severity::Warning);
    }
}
