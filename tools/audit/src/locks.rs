//! LOCK-ORDER: the static deadlock detector.
//!
//! Extracts a lock-acquisition-order graph from `Mutex`/`RwLock` guard
//! scopes: every `recv.lock()` / `.read()` / `.write()` (empty-paren,
//! so `io::Write::write(buf)` never matches) and every call to a
//! guard-returning free helper (`sync::lock_recover(&self.state)`) is
//! an acquisition.  The receiver chain is resolved against the symbol
//! table to a stable lock *identity* — `Struct.field` for lock-typed
//! fields reached from `self`/typed params, `static NAME` for
//! lock-typed statics, and through guard-returning wrapper methods
//! (`Registry::lock` → the `Mutex` field it locks internally).
//! Receivers the resolver cannot type (locals, indexed slots, tuple
//! fields) are dropped rather than guessed: a misattributed identity
//! could alias two unrelated locks and fabricate a cycle.
//!
//! Hold ranges are syntactic: a `let`-bound guard is held to the end
//! of its enclosing block, a temporary to the end of its statement
//! (or the `{…}` it opens, for `match m.lock() { … }`).  A second
//! acquisition inside a hold range adds the edge `first → second`; a
//! *call* inside a hold range adds edges to every lock the callee may
//! eventually take (transitively, via the PANIC-REACH resolver).  Any
//! cycle in the resulting graph — including a self-edge, i.e. a
//! re-entrant acquisition of a non-reentrant `std` lock — is reported
//! with both acquisition sites of every edge in the cycle.

use crate::callgraph::{extract, scope_mask, Call, Resolver};
use crate::parse::{is_ident_byte, line_at, skip_ws_b, CrateModel};
use crate::rules::{match_paren, Finding, Severity};
use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};
use std::ops::Range;

const LOCK_METHODS: &[&str] = &["lock", "read", "write"];

/// One resolved lock acquisition inside a fn body.
struct Acq {
    /// Offset of the receiver expression (hold ranges start here).
    off: usize,
    /// Stable lock identity (`Store.inner`, `static GATE`).
    id: String,
    /// Byte range over which the guard is (conservatively) held.
    hold: Range<usize>,
    line: usize,
}

/// Strip references, lifetimes and `Arc`/`Rc`/`Box` wrappers down to
/// the bare type name: `&'a Arc<pool::Shared>` → `Shared`.
fn base_type(ty: &str) -> String {
    let mut s = ty.trim();
    loop {
        s = s.trim_start_matches('&').trim_start();
        if s.starts_with('\'') {
            match s.find(char::is_whitespace) {
                Some(w) => s = s[w..].trim_start(),
                None => return String::new(),
            }
            continue;
        }
        s = s.strip_prefix("mut ").unwrap_or(s).trim_start();
        s = s.strip_prefix("dyn ").unwrap_or(s).trim_start();
        let head_end = s.find('<').unwrap_or(s.len());
        let last = s[..head_end].rsplit("::").next().unwrap_or("").trim();
        if matches!(last, "Arc" | "Rc" | "Box") && head_end < s.len() {
            if let Some(close) = s.rfind('>') {
                s = s[head_end + 1..close].trim();
                continue;
            }
        }
        return last.to_string();
    }
}

/// Walk a `a.b.c` receiver chain backwards from the `.` at `dot`.
/// Returns `(chain, offset of the chain root)`; `None` for receivers
/// that are not plain field chains (calls, indexing, paths).
fn chain_back(code: &str, mut dot: usize) -> Option<(Vec<String>, usize)> {
    let b = code.as_bytes();
    let mut parts = Vec::new();
    loop {
        let mut s = dot;
        while s > 0 && is_ident_byte(b[s - 1]) {
            s -= 1;
        }
        if s == dot {
            return None; // `foo()[i].lock()` and friends
        }
        parts.push(code[s..dot].to_string());
        if s >= 1 && b[s - 1] == b'.' {
            dot = s - 1;
            continue;
        }
        if s >= 2 && b[s - 1] == b':' && b[s - 2] == b':' {
            return None; // `module::ITEM.lock()` path roots — punt
        }
        parts.reverse();
        return Some((parts, s));
    }
}

fn is_all_caps(s: &str) -> bool {
    !s.is_empty()
        && s.bytes().all(|b| b.is_ascii_uppercase() || b.is_ascii_digit() || b == b'_')
        && s.bytes().any(|b| b.is_ascii_uppercase())
}

/// Find the struct named `name`, preferring a definition in the same
/// file as `fn_idx` (same-named structs across modules stay distinct).
fn find_struct<'a>(
    model: &'a CrateModel,
    fn_idx: usize,
    name: &str,
) -> Option<&'a crate::parse::StructDef> {
    let file = model.fns[fn_idx].file;
    model
        .structs
        .iter()
        .find(|s| s.name == name && s.file == file)
        .or_else(|| model.structs.iter().find(|s| s.name == name))
}

/// Resolve a receiver chain (rooted at `self`, a typed param, or a
/// static) to a lock identity.  `method` carries the acquisition
/// method name when the site was `recv.lock()`-shaped, enabling the
/// guard-returning-wrapper fallback; it is `None` when the chain is a
/// lock expression passed to a guard-returning free fn.
fn resolve_chain(
    model: &CrateModel,
    fn_idx: usize,
    chain: &[String],
    method: Option<&str>,
    memo: &mut HashMap<(String, String), Option<String>>,
    visiting: &mut HashSet<(String, String)>,
) -> Option<String> {
    let f = &model.fns[fn_idx];
    let root = chain[0].as_str();
    let mut cur: String;
    if root == "self" {
        cur = f.qual.clone()?;
    } else if let Some((_, ty)) = f.params().into_iter().find(|(n, _)| n == root) {
        if CrateModel::is_lock_type(&ty) {
            // A lock-typed param: the identity belongs to the caller.
            // Guard-returning wrappers get re-resolved at call sites;
            // anything else stays anonymous.
            return None;
        }
        cur = base_type(&ty);
    } else if is_all_caps(root) {
        let file = f.file;
        let st = model
            .statics
            .iter()
            .find(|s| s.name == root && s.file == file)
            .or_else(|| model.statics.iter().find(|s| s.name == root))?;
        if CrateModel::is_lock_type(&st.ty) {
            return if chain.len() == 1 {
                Some(format!("static {}", st.name))
            } else {
                None
            };
        }
        cur = base_type(&st.ty);
    } else {
        return None; // untyped local — anonymous
    }

    if chain.len() == 1 {
        // `self.lock()` / `reg.lock()` on a non-lock type: delegate to
        // that type's guard-returning wrapper, if it has one.
        return wrapper_internal(model, &cur, method?, memo, visiting);
    }
    for (k, seg) in chain.iter().enumerate().skip(1) {
        let sd = find_struct(model, fn_idx, &cur)?;
        let fd = sd.fields.iter().find(|fd| &fd.name == seg)?;
        if k == chain.len() - 1 {
            if CrateModel::is_lock_type(&fd.ty) {
                return Some(format!("{}.{}", sd.name, fd.name));
            }
            return wrapper_internal(model, &base_type(&fd.ty), method?, memo, visiting);
        }
        cur = base_type(&fd.ty);
    }
    None
}

/// The lock a guard-returning wrapper method (`Registry::lock`) takes
/// internally: the first `self`-rooted acquisition in its body.
fn wrapper_internal(
    model: &CrateModel,
    tname: &str,
    method: &str,
    memo: &mut HashMap<(String, String), Option<String>>,
    visiting: &mut HashSet<(String, String)>,
) -> Option<String> {
    let key = (tname.to_string(), method.to_string());
    if let Some(v) = memo.get(&key) {
        return v.clone();
    }
    if !visiting.insert(key.clone()) {
        return None; // delegation cycle — give up
    }
    let result = (|| {
        let idx = model.fns.iter().position(|g| {
            g.qual.as_deref() == Some(tname)
                && g.name == method
                && g.returns_guard()
                && !g.is_test
                && g.body.is_some()
        })?;
        for (_, chain, word) in scan_method_sites(model, idx) {
            if chain[0] == "self" {
                if let Some(id) =
                    resolve_chain(model, idx, &chain, Some(word), memo, visiting)
                {
                    return Some(id);
                }
            }
        }
        None
    })();
    visiting.remove(&key);
    memo.insert(key, result.clone());
    result
}

/// Raw `recv.lock()`-shaped sites in a fn body: `(root offset,
/// receiver chain, method name)`.  Nested fn bodies are skipped.
fn scan_method_sites(
    model: &CrateModel,
    idx: usize,
) -> Vec<(usize, Vec<String>, &'static str)> {
    let f = &model.fns[idx];
    let file = &model.files[f.file];
    let code = &file.code;
    let b = code.as_bytes();
    let range = f.body.clone().unwrap_or(0..0);
    let inner: Vec<Range<usize>> = file
        .fns
        .iter()
        .filter(|&&j| j != idx)
        .filter_map(|&j| model.fns[j].body.clone())
        .filter(|r| r.start >= range.start && r.end <= range.end)
        .collect();

    let mut out = Vec::new();
    let mut i = range.start;
    while i < range.end {
        if let Some(r) = inner.iter().find(|r| r.contains(&i)) {
            i = r.end;
            continue;
        }
        let c = b[i];
        if (!c.is_ascii_alphabetic() && c != b'_') || (i > 0 && is_ident_byte(b[i - 1])) {
            i += 1;
            continue;
        }
        let s = i;
        let mut e = i;
        while e < range.end && is_ident_byte(b[e]) {
            e += 1;
        }
        i = e;
        let word = &code[s..e];
        let Some(&w) = LOCK_METHODS.iter().find(|&&m| m == word) else { continue };
        if s == 0 || b[s - 1] != b'.' {
            continue;
        }
        // Empty parens only: `.read()` is RwLock, `.read(buf)` is io.
        let j = skip_ws_b(b, e);
        if b.get(j) != Some(&b'(') {
            continue;
        }
        let j2 = skip_ws_b(b, j + 1);
        if b.get(j2) != Some(&b')') {
            continue;
        }
        if let Some((chain, root)) = chain_back(code, s - 1) {
            out.push((root, chain, w));
        }
    }
    out
}

/// Calls to guard-returning free fns (`lock_recover(&self.state, …)`):
/// `(call offset, lock-expression chain)`.
fn scan_guard_calls(
    model: &CrateModel,
    idx: usize,
    guard_free: &HashSet<&str>,
) -> Vec<(usize, Vec<String>)> {
    let f = &model.fns[idx];
    let file = &model.files[f.file];
    let code = &file.code;
    let b = code.as_bytes();
    let range = f.body.clone().unwrap_or(0..0);
    let inner: Vec<Range<usize>> = file
        .fns
        .iter()
        .filter(|&&j| j != idx)
        .filter_map(|&j| model.fns[j].body.clone())
        .filter(|r| r.start >= range.start && r.end <= range.end)
        .collect();

    let mut out = Vec::new();
    let mut i = range.start;
    while i < range.end {
        if let Some(r) = inner.iter().find(|r| r.contains(&i)) {
            i = r.end;
            continue;
        }
        let c = b[i];
        if (!c.is_ascii_alphabetic() && c != b'_') || (i > 0 && is_ident_byte(b[i - 1])) {
            i += 1;
            continue;
        }
        let s = i;
        let mut e = i;
        while e < range.end && is_ident_byte(b[e]) {
            e += 1;
        }
        i = e;
        let word = &code[s..e];
        if !guard_free.contains(word) || (s > 0 && b[s - 1] == b'.') {
            continue;
        }
        let j = skip_ws_b(b, e);
        if b.get(j) != Some(&b'(') {
            continue;
        }
        let Some(close) = match_paren(code, j) else { continue };
        let args = &code[j + 1..close - 1];
        let first = crate::parse::split_top_level(args, b',')
            .first()
            .map(|(_, p)| p.trim())
            .unwrap_or("");
        let expr = first.trim_start_matches('&').trim_start();
        let expr = expr.strip_prefix("mut ").unwrap_or(expr);
        if !expr.is_empty() && expr.bytes().all(|b| is_ident_byte(b) || b == b'.') {
            let chain: Vec<String> = expr.split('.').map(str::to_string).collect();
            if chain.iter().all(|p| !p.is_empty()) {
                out.push((s, chain));
            }
        }
    }
    out
}

/// End offset of the innermost `{…}` block containing `off`.
fn enclosing_block_end(code: &str, off: usize, body: &Range<usize>) -> usize {
    let b = code.as_bytes();
    let mut stack: Vec<usize> = Vec::new();
    for i in body.start..body.end {
        match b[i] {
            b'{' => stack.push(i),
            b'}' => {
                if let Some(o) = stack.pop() {
                    if o < off && off < i {
                        return i;
                    }
                }
            }
            _ => {}
        }
    }
    body.end
}

/// Conservative guard hold range for an acquisition whose receiver
/// expression starts at `expr_start`.
fn hold_range(code: &str, expr_start: usize, body: &Range<usize>) -> Range<usize> {
    let b = code.as_bytes();
    // `let`-bound (incl. `if let` / `while let`)?  Scan back to the
    // statement boundary and look for the keyword.
    let mut k = expr_start;
    while k > body.start && !matches!(b[k - 1], b';' | b'{' | b'}') {
        k -= 1;
    }
    let bound = !crate::rules::word_occurrences(&code[k..expr_start], "let").is_empty();
    if bound {
        return expr_start..enclosing_block_end(code, expr_start, body);
    }
    // Temporary: held to the end of the statement, or through the
    // block it opens (`match m.lock() { … }`).
    let mut depth = 0i64;
    let mut i = expr_start;
    while i < body.end {
        match b[i] {
            b'(' | b'[' => depth += 1,
            b')' | b']' => {
                if depth == 0 {
                    return expr_start..i;
                }
                depth -= 1;
            }
            b'{' if depth == 0 => {
                let end = crate::parse::match_delim_b(b, i, b'{', b'}')
                    .unwrap_or(body.end);
                return expr_start..end;
            }
            b'}' if depth == 0 => return expr_start..i,
            b';' if depth == 0 => return expr_start..i,
            _ => {}
        }
        i += 1;
    }
    expr_start..body.end
}

/// Resolved acquisitions for one fn.
fn extract_acqs(
    model: &CrateModel,
    idx: usize,
    guard_free: &HashSet<&str>,
    memo: &mut HashMap<(String, String), Option<String>>,
) -> Vec<Acq> {
    let f = &model.fns[idx];
    let file = &model.files[f.file];
    let body = f.body.clone().unwrap_or(0..0);
    let mut visiting = HashSet::new();
    let mut out = Vec::new();
    for (root, chain, word) in scan_method_sites(model, idx) {
        if let Some(id) =
            resolve_chain(model, idx, &chain, Some(word), memo, &mut visiting)
        {
            out.push(Acq {
                off: root,
                id,
                hold: hold_range(&file.code, root, &body),
                line: line_at(&file.code, root),
            });
        }
    }
    for (off, chain) in scan_guard_calls(model, idx, guard_free) {
        if let Some(id) = resolve_chain(model, idx, &chain, None, memo, &mut visiting) {
            out.push(Acq {
                off,
                id,
                hold: hold_range(&file.code, off, &body),
                line: line_at(&file.code, off),
            });
        }
    }
    out.sort_by_key(|a| a.off);
    out
}

/// Locks fn `i` (or anything it transitively calls) may acquire:
/// `id → (path, line)` of a representative site.
#[allow(clippy::too_many_arguments)]
fn eventual(
    i: usize,
    model: &CrateModel,
    acqs: &[Vec<Acq>],
    calls: &[Vec<Call>],
    resolver: &Resolver<'_>,
    memo: &mut Vec<Option<BTreeMap<String, (String, usize)>>>,
    visiting: &mut Vec<bool>,
) -> BTreeMap<String, (String, usize)> {
    if let Some(m) = &memo[i] {
        return m.clone();
    }
    if visiting[i] {
        return BTreeMap::new(); // recursion: fixpoint approximation
    }
    visiting[i] = true;
    let mut map: BTreeMap<String, (String, usize)> = BTreeMap::new();
    let path = &model.files[model.fns[i].file].path;
    for a in &acqs[i] {
        map.entry(a.id.clone()).or_insert_with(|| (path.clone(), a.line));
    }
    for c in &calls[i] {
        if LOCK_METHODS.contains(&c.name.as_str()) {
            continue; // acquisition scan owns these
        }
        for t in resolver.resolve(c, i) {
            for (id, site) in eventual(t, model, acqs, calls, resolver, memo, visiting)
            {
                map.entry(id).or_insert(site);
            }
        }
    }
    visiting[i] = false;
    memo[i] = Some(map.clone());
    map
}

type EdgeMap = BTreeMap<(String, String), (String, usize, String, usize)>;

/// Tarjan SCC over the identity graph (iterative would be overkill —
/// the graph has a handful of nodes).
struct Tarjan<'a> {
    adj: &'a BTreeMap<usize, BTreeSet<usize>>,
    index: Vec<Option<usize>>,
    low: Vec<usize>,
    on_stack: Vec<bool>,
    stack: Vec<usize>,
    next: usize,
    sccs: Vec<Vec<usize>>,
}

impl Tarjan<'_> {
    fn strongconnect(&mut self, v: usize) {
        self.index[v] = Some(self.next);
        self.low[v] = self.next;
        self.next += 1;
        self.stack.push(v);
        self.on_stack[v] = true;
        if let Some(ws) = self.adj.get(&v) {
            for &w in ws {
                if self.index[w].is_none() {
                    self.strongconnect(w);
                    self.low[v] = self.low[v].min(self.low[w]);
                } else if self.on_stack[w] {
                    self.low[v] = self.low[v].min(self.index[w].unwrap_or(0));
                }
            }
        }
        if Some(self.low[v]) == self.index[v] {
            let mut scc = Vec::new();
            while let Some(w) = self.stack.pop() {
                self.on_stack[w] = false;
                scc.push(w);
                if w == v {
                    break;
                }
            }
            scc.sort_unstable();
            self.sccs.push(scc);
        }
    }
}

/// The LOCK-ORDER pass.
pub fn lock_order(model: &CrateModel, out: &mut Vec<Finding>) {
    let n = model.fns.len();
    let in_scope = scope_mask(model);
    let resolver = Resolver::build(model, &in_scope);
    let guard_free: HashSet<&str> = model
        .fns
        .iter()
        .enumerate()
        .filter(|(i, f)| in_scope[*i] && f.qual.is_none() && f.returns_guard())
        .map(|(_, f)| f.name.as_str())
        .collect();

    let mut wrap_memo: HashMap<(String, String), Option<String>> = HashMap::new();
    let acqs: Vec<Vec<Acq>> = (0..n)
        .map(|i| {
            if in_scope[i] {
                extract_acqs(model, i, &guard_free, &mut wrap_memo)
            } else {
                Vec::new()
            }
        })
        .collect();
    let calls: Vec<Vec<Call>> = (0..n)
        .map(|i| if in_scope[i] { extract(model, i).calls } else { Vec::new() })
        .collect();

    let mut ev_memo: Vec<Option<BTreeMap<String, (String, usize)>>> = vec![None; n];
    let mut visiting = vec![false; n];

    let mut edges: EdgeMap = BTreeMap::new();
    for i in 0..n {
        if acqs[i].is_empty() {
            continue;
        }
        let path = model.files[model.fns[i].file].path.clone();
        for a in &acqs[i] {
            for b2 in &acqs[i] {
                if b2.off > a.off && b2.off < a.hold.end {
                    edges
                        .entry((a.id.clone(), b2.id.clone()))
                        .or_insert((path.clone(), a.line, path.clone(), b2.line));
                }
            }
            for c in &calls[i] {
                if c.off <= a.off
                    || c.off >= a.hold.end
                    || LOCK_METHODS.contains(&c.name.as_str())
                {
                    continue;
                }
                for t in resolver.resolve(c, i) {
                    let ev = eventual(
                        t, model, &acqs, &calls, &resolver, &mut ev_memo,
                        &mut visiting,
                    );
                    for (id2, (p2, l2)) in ev {
                        edges
                            .entry((a.id.clone(), id2))
                            .or_insert((path.clone(), a.line, p2, l2));
                    }
                }
            }
        }
    }

    // Identity graph → SCCs.
    let nodes: Vec<String> = edges
        .keys()
        .flat_map(|(a, b)| [a.clone(), b.clone()])
        .collect::<BTreeSet<_>>()
        .into_iter()
        .collect();
    let node_ix: BTreeMap<&str, usize> =
        nodes.iter().enumerate().map(|(i, s)| (s.as_str(), i)).collect();
    let mut adj: BTreeMap<usize, BTreeSet<usize>> = BTreeMap::new();
    for (a, b) in edges.keys() {
        adj.entry(node_ix[a.as_str()])
            .or_default()
            .insert(node_ix[b.as_str()]);
    }
    let mut t = Tarjan {
        adj: &adj,
        index: vec![None; nodes.len()],
        low: vec![0; nodes.len()],
        on_stack: vec![false; nodes.len()],
        stack: Vec::new(),
        next: 0,
        sccs: Vec::new(),
    };
    for v in 0..nodes.len() {
        if t.index[v].is_none() {
            t.strongconnect(v);
        }
    }
    let mut sccs = t.sccs;
    sccs.sort();

    for scc in sccs {
        let cyclic = scc.len() > 1
            || edges.contains_key(&(nodes[scc[0]].clone(), nodes[scc[0]].clone()));
        if !cyclic {
            continue;
        }
        let member: BTreeSet<&str> = scc.iter().map(|&v| nodes[v].as_str()).collect();
        let intra: Vec<(&(String, String), &(String, usize, String, usize))> = edges
            .iter()
            .filter(|((a, b), _)| {
                member.contains(a.as_str()) && member.contains(b.as_str())
            })
            .collect();
        let Some((_, (_, _, ap, al))) = intra.first() else { continue };
        let parts: Vec<String> = intra
            .iter()
            .map(|((a, b), (p1, l1, p2, l2))| {
                format!("{a} ({p1}:{l1}) then {b} ({p2}:{l2})")
            })
            .collect();
        out.push(Finding {
            path: ap.clone(),
            line: *al,
            rule: "LOCK-ORDER",
            severity: Severity::Error,
            message: format!(
                "lock-order cycle: {} — acquire these locks in one global order (or \
                 collapse them into one) so no interleaving can deadlock",
                parts.join("; ")
            ),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::scan;

    fn run(files: &[(&str, &str)]) -> Vec<Finding> {
        let mut m = CrateModel::default();
        for (p, src) in files {
            m.add_file(p.to_string(), scan(src));
        }
        let mut out = Vec::new();
        lock_order(&m, &mut out);
        out
    }

    const TWO_LOCK_STRUCT: &str = "use std::sync::Mutex;\npub struct S {\n    a: Mutex<u32>,\n    b: Mutex<u32>,\n}\n";

    #[test]
    fn two_mutex_cycle_is_reported_with_both_sites() {
        let src = format!(
            "{TWO_LOCK_STRUCT}impl S {{\n    fn ab(&self) {{\n        let ga = self.a.lock();\n        let gb = self.b.lock();\n        drop(gb);\n        drop(ga);\n    }}\n    fn ba(&self) {{\n        let gb = self.b.lock();\n        let ga = self.a.lock();\n        drop(ga);\n        drop(gb);\n    }}\n}}\n"
        );
        let got = run(&[("rust/src/serve/s.rs", &src)]);
        assert_eq!(got.len(), 1, "{got:?}");
        let f = &got[0];
        assert_eq!(f.rule, "LOCK-ORDER");
        // Both edges, each with both acquisition sites.
        assert!(f.message.contains("S.a (rust/src/serve/s.rs:8) then S.b (rust/src/serve/s.rs:9)"), "{}", f.message);
        assert!(f.message.contains("S.b (rust/src/serve/s.rs:14) then S.a (rust/src/serve/s.rs:15)"), "{}", f.message);
    }

    #[test]
    fn consistent_order_is_clean() {
        let src = format!(
            "{TWO_LOCK_STRUCT}impl S {{\n    fn ab(&self) {{\n        let ga = self.a.lock();\n        let gb = self.b.lock();\n        drop(gb);\n        drop(ga);\n    }}\n    fn ab2(&self) {{\n        let ga = self.a.lock();\n        let gb = self.b.lock();\n        drop(gb);\n        drop(ga);\n    }}\n}}\n"
        );
        let got = run(&[("rust/src/serve/s.rs", &src)]);
        assert!(got.is_empty(), "{got:?}");
    }

    #[test]
    fn sequential_temporaries_do_not_create_edges() {
        let src = format!(
            "{TWO_LOCK_STRUCT}impl S {{\n    fn seq(&self) {{\n        self.a.lock();\n        self.b.lock();\n    }}\n    fn seq2(&self) {{\n        self.b.lock();\n        self.a.lock();\n    }}\n}}\n"
        );
        let got = run(&[("rust/src/serve/s.rs", &src)]);
        assert!(got.is_empty(), "temporaries drop at the `;`: {got:?}");
    }

    #[test]
    fn cycle_through_a_callee_is_found_transitively() {
        let a = format!(
            "{TWO_LOCK_STRUCT}impl S {{\n    fn hold_a_then_b(&self) {{\n        let g = self.a.lock();\n        self.take_b();\n        drop(g);\n    }}\n    fn take_b(&self) {{\n        let g = self.b.lock();\n        drop(g);\n    }}\n    fn hold_b_then_a(&self) {{\n        let g = self.b.lock();\n        let h = self.a.lock();\n        drop(h);\n        drop(g);\n    }}\n}}\n"
        );
        let got = run(&[("rust/src/par/s.rs", &a)]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("S.a"), "{}", got[0].message);
        assert!(got[0].message.contains("S.b"), "{}", got[0].message);
    }

    #[test]
    fn guard_returning_wrapper_and_free_helper_resolve_to_the_inner_lock() {
        let obs = "use std::sync::{Mutex, MutexGuard};\npub struct Registry {\n    inner: Mutex<Vec<u32>>,\n}\nimpl Registry {\n    pub fn lock(&self) -> MutexGuard<'_, Vec<u32>> {\n        self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner)\n    }\n}\n";
        let serve = "use std::sync::{Mutex, MutexGuard};\npub fn lock_recover<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {\n    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)\n}\npub struct Store {\n    jobs: Mutex<Vec<u32>>,\n    reg: crate::obs::Registry,\n}\nimpl Store {\n    fn jobs_then_reg(&self) {\n        let g = lock_recover(&self.jobs);\n        let r = self.reg.lock();\n        drop(r);\n        drop(g);\n    }\n    fn reg_then_jobs(&self) {\n        let r = self.reg.lock();\n        let g = lock_recover(&self.jobs);\n        drop(g);\n        drop(r);\n    }\n}\n";
        let got = run(&[
            ("rust/src/obs/metrics.rs", obs),
            ("rust/src/serve/store.rs", serve),
        ]);
        assert_eq!(got.len(), 1, "{got:?}");
        let m = &got[0].message;
        assert!(m.contains("Registry.inner"), "{m}");
        assert!(m.contains("Store.jobs"), "{m}");
    }

    #[test]
    fn reentrant_same_lock_is_a_self_cycle() {
        let src = format!(
            "{TWO_LOCK_STRUCT}impl S {{\n    fn reenter(&self) {{\n        let g = self.a.lock();\n        let h = self.a.lock();\n        drop(h);\n        drop(g);\n    }}\n}}\n"
        );
        let got = run(&[("rust/src/kern/cache.rs", &src)]);
        assert_eq!(got.len(), 1, "{got:?}");
        assert!(got[0].message.contains("S.a"), "{}", got[0].message);
    }
}
