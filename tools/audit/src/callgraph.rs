//! PANIC-REACH: the interprocedural panic-reachability walk.
//!
//! Builds an approximate call graph over the [`CrateModel`] symbol
//! table and BFS-walks it from every serve-layer entry point (HTTP
//! `route`/`handle_*`/connection loops, the queue `worker_loop`).  Any
//! function reachable from an entry that contains a panic-capable
//! construct — `panic!`-family macro, `.unwrap()`, `.expect()`, or (on
//! the request-parsing surface) an unchecked index/slice expression —
//! is flagged, unless the construct sits inside a `catch_unwind(…)`
//! argument or behind a reasoned allow marker.
//!
//! The resolution is deliberately an over-approximation (see DESIGN.md
//! §"Static analysis & invariants" for the full can/cannot-see list):
//! a method call `x.get(…)` with an untyped receiver resolves to every
//! user-defined method named `get`; `self.m(…)` narrows to the current
//! `impl` block's type when that type defines `m`; `Type::m(…)` and
//! `Self::m(…)` resolve exactly; bare `helper(…)` prefers a free fn in
//! the same file.  Unresolved names (std, closures, fn pointers) drop
//! out of the walk rather than poisoning it.  Double-reporting against
//! the intra-file rules is avoided by kind-scoping: inside the serve
//! request path only Index sites fire here (PANIC-UNWRAP already owns
//! `.unwrap()`/`panic!` there), and Index sites are only collected on
//! the untrusted-input parsing surface (`serve/http.rs`,
//! `serve/protocol.rs`) where a bad byte offset is a remote panic.

use crate::parse::{is_ident_byte, line_at, skip_angles, skip_ws_b, CrateModel};
use crate::rules::{match_paren, word_occurrences, Finding, Severity};
use std::collections::{BTreeSet, HashMap, VecDeque};
use std::ops::Range;

/// Keywords that look like `word (` in code but are never calls.
const KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "return", "loop", "in", "as", "let", "mut",
    "ref", "move", "fn", "else", "break", "continue", "unsafe", "impl", "dyn",
    "where", "use", "pub", "crate", "super", "self", "await", "async",
    "static", "const", "type", "struct", "enum", "trait", "mod",
];

/// Serve entry points the walk starts from (exact names; `handle_`
/// prefixed fns are added on top).
const ENTRY_NAMES: &[&str] = &["route", "handle_connection", "accept_loop", "worker_loop"];

pub(crate) enum CallKind {
    /// `self.m(…)` — narrows to the enclosing impl type when possible.
    SelfMethod,
    /// `expr.m(…)` with an untyped receiver.
    Method,
    /// `Type::m(…)` / `Self::m(…)`.
    Qualified(String),
    /// `helper(…)` or `module::helper(…)`.
    Free,
}

pub(crate) struct Call {
    /// Byte offset of the callee name in the file's code text (the
    /// LOCK-ORDER pass tests it against guard hold ranges).
    pub(crate) off: usize,
    pub(crate) name: String,
    pub(crate) kind: CallKind,
}

#[derive(Clone, Copy, PartialEq)]
pub(crate) enum PanicKind {
    Macro,
    Unwrap,
    Expect,
    Index,
}

pub(crate) struct Site {
    pub(crate) off: usize,
    pub(crate) kind: PanicKind,
}

pub(crate) struct FnInfo {
    pub(crate) calls: Vec<Call>,
    pub(crate) sites: Vec<Site>,
}

fn is_serve_request_path(path: &str) -> bool {
    path.starts_with("rust/src/serve/") && !path.ends_with("loadgen.rs")
}

/// The untrusted-input parsing surface where Index sites are collected.
fn is_index_surface(path: &str) -> bool {
    is_serve_request_path(path)
        && (path.ends_with("/http.rs") || path.ends_with("/protocol.rs"))
}

/// Extract call sites and panic sites from one fn body, skipping
/// nested fn bodies (their sites belong to the nested fn) and
/// `catch_unwind(…)` argument spans (shielded).
pub(crate) fn extract(model: &CrateModel, idx: usize) -> FnInfo {
    let f = &model.fns[idx];
    let file = &model.files[f.file];
    let code = &file.code;
    let b = code.as_bytes();
    let range = f.body.clone().unwrap_or(0..0);

    let inner: Vec<Range<usize>> = file
        .fns
        .iter()
        .filter(|&&j| j != idx)
        .filter_map(|&j| model.fns[j].body.clone())
        .filter(|r| r.start >= range.start && r.end <= range.end)
        .collect();

    let mut shields: Vec<Range<usize>> = Vec::new();
    for off in word_occurrences(code, "catch_unwind") {
        if off < range.start || off >= range.end {
            continue;
        }
        let j = skip_ws_b(b, off + "catch_unwind".len());
        if b.get(j) == Some(&b'(') {
            shields.push(j..match_paren(code, j).unwrap_or(range.end));
        }
    }
    let shielded = |o: usize| shields.iter().any(|s| s.contains(&o));

    let mut calls = Vec::new();
    let mut sites = Vec::new();
    let mut i = range.start;
    while i < range.end {
        if let Some(r) = inner.iter().find(|r| r.contains(&i)) {
            i = r.end;
            continue;
        }
        let c = b[i];
        if c == b'[' {
            let p = if i > 0 { b[i - 1] } else { b' ' };
            if (is_ident_byte(p) || p == b')' || p == b']') && !shielded(i) {
                sites.push(Site { off: i, kind: PanicKind::Index });
            }
            i += 1;
            continue;
        }
        if (!c.is_ascii_alphabetic() && c != b'_') || (i > 0 && is_ident_byte(b[i - 1])) {
            i += 1;
            continue;
        }
        let s = i;
        let mut e = i;
        while e < range.end && is_ident_byte(b[e]) {
            e += 1;
        }
        i = e;
        let word = &code[s..e];
        let j0 = skip_ws_b(b, e);

        if matches!(word, "panic" | "unreachable" | "todo" | "unimplemented")
            && b.get(j0) == Some(&b'!')
        {
            if !shielded(s) {
                sites.push(Site { off: s, kind: PanicKind::Macro });
            }
            continue;
        }
        if b.get(j0) == Some(&b'!') {
            continue; // some other macro invocation, not a call
        }

        let prev_dot = s > 0 && b[s - 1] == b'.';
        if prev_dot && (word == "unwrap" || word == "expect") && b.get(j0) == Some(&b'(') {
            // `.lock().unwrap()` chains are PANIC-LOCK's domain.
            let on_lock = code[..s - 1].trim_end().ends_with("lock()");
            if !on_lock && !shielded(s) {
                let kind =
                    if word == "unwrap" { PanicKind::Unwrap } else { PanicKind::Expect };
                sites.push(Site { off: s, kind });
            }
            continue;
        }

        if KEYWORDS.contains(&word) {
            continue;
        }
        let mut j = j0;
        if code[j..].starts_with("::<") {
            j = skip_ws_b(b, skip_angles(b, j + 2));
        }
        if b.get(j) != Some(&b'(') || shielded(s) {
            continue;
        }

        let kind = if prev_dot {
            let mut rs = s - 1;
            while rs > 0 && is_ident_byte(b[rs - 1]) {
                rs -= 1;
            }
            let pure_self =
                &code[rs..s - 1] == "self" && (rs == 0 || b[rs - 1] != b'.');
            if pure_self { CallKind::SelfMethod } else { CallKind::Method }
        } else if s >= 2 && b[s - 1] == b':' && b[s - 2] == b':' {
            let qe = s - 2;
            let mut qs = qe;
            while qs > 0 && is_ident_byte(b[qs - 1]) {
                qs -= 1;
            }
            let q = &code[qs..qe];
            if q.is_empty() {
                continue; // `>::name(` turbofish tail or `::name(` — punt
            }
            if q.as_bytes()[0].is_ascii_uppercase() || q == "Self" {
                CallKind::Qualified(q.to_string())
            } else {
                CallKind::Free // `module::helper(…)` — resolve by name
            }
        } else {
            // Skip the name of a nested `fn name(…)` definition and
            // uppercase constructors (`Some(…)`, `Wrapper(…)`).
            let mut k = s;
            while k > range.start && b[k - 1].is_ascii_whitespace() {
                k -= 1;
            }
            let is_def = k >= 2
                && &code[k - 2..k] == "fn"
                && (k < 3 || !is_ident_byte(b[k - 3]));
            if is_def || word.as_bytes()[0].is_ascii_uppercase() {
                continue;
            }
            CallKind::Free
        };
        calls.push(Call { off: s, name: word.to_string(), kind });
    }
    FnInfo { calls, sites }
}

/// Name-resolution index over the symbol table, shared by PANIC-REACH
/// and LOCK-ORDER.
pub(crate) struct Resolver<'a> {
    model: &'a CrateModel,
    free: HashMap<&'a str, Vec<usize>>,
    exact: HashMap<(&'a str, &'a str), Vec<usize>>,
    by_name: HashMap<&'a str, Vec<usize>>,
}

impl<'a> Resolver<'a> {
    /// Index every non-test fn-with-body under `rust/src/`.
    pub(crate) fn build(model: &'a CrateModel, in_scope: &[bool]) -> Self {
        let mut r = Resolver {
            model,
            free: HashMap::new(),
            exact: HashMap::new(),
            by_name: HashMap::new(),
        };
        for (i, f) in model.fns.iter().enumerate() {
            if !in_scope[i] {
                continue;
            }
            match &f.qual {
                None => r.free.entry(f.name.as_str()).or_default().push(i),
                Some(q) => {
                    r.exact.entry((q.as_str(), f.name.as_str())).or_default().push(i);
                    r.by_name.entry(f.name.as_str()).or_default().push(i);
                }
            }
        }
        r
    }

    /// Resolve one call site (in `caller`) to candidate fn indices.
    pub(crate) fn resolve(&self, c: &Call, caller: usize) -> Vec<usize> {
        match &c.kind {
            CallKind::Free => {
                let all = self.free.get(c.name.as_str()).cloned().unwrap_or_default();
                let same_file: Vec<usize> = all
                    .iter()
                    .copied()
                    .filter(|&t| self.model.fns[t].file == self.model.fns[caller].file)
                    .collect();
                if same_file.is_empty() { all } else { same_file }
            }
            CallKind::SelfMethod => {
                if let Some(q) = &self.model.fns[caller].qual {
                    if let Some(v) = self.exact.get(&(q.as_str(), c.name.as_str())) {
                        return v.clone();
                    }
                }
                self.by_name.get(c.name.as_str()).cloned().unwrap_or_default()
            }
            CallKind::Method => {
                self.by_name.get(c.name.as_str()).cloned().unwrap_or_default()
            }
            CallKind::Qualified(t) => {
                let t = if t == "Self" {
                    match &self.model.fns[caller].qual {
                        Some(q) => q.as_str(),
                        None => return Vec::new(),
                    }
                } else {
                    t.as_str()
                };
                self.exact.get(&(t, c.name.as_str())).cloned().unwrap_or_default()
            }
        }
    }
}

/// Per-fn analysis scope shared by the interprocedural passes: a
/// non-test fn with a body in a file under `rust/src/`.
pub(crate) fn scope_mask(model: &CrateModel) -> Vec<bool> {
    model
        .fns
        .iter()
        .map(|f| {
            !f.is_test
                && f.body.is_some()
                && model.files[f.file].path.starts_with("rust/src/")
        })
        .collect()
}

fn display_name(model: &CrateModel, i: usize) -> String {
    let f = &model.fns[i];
    match &f.qual {
        Some(q) => format!("{q}::{}", f.name),
        None => f.name.clone(),
    }
}

/// Entry → … → `i` call chain for the diagnostic, via BFS parents.
fn chain_of(model: &CrateModel, parent: &[Option<usize>], i: usize) -> String {
    let mut idxs = vec![i];
    let mut cur = i;
    while let Some(p) = parent[cur] {
        idxs.push(p);
        cur = p;
        if idxs.len() > 32 {
            break; // BFS parents are acyclic; belt and braces
        }
    }
    idxs.reverse();
    let names: Vec<String> = idxs.iter().map(|&k| display_name(model, k)).collect();
    names.join(" -> ")
}

/// The PANIC-REACH pass: walk the call graph from every serve entry
/// and flag reachable panic-capable sites.
pub fn panic_reach(model: &CrateModel, out: &mut Vec<Finding>) {
    let n = model.fns.len();
    let in_scope = scope_mask(model);
    let infos: Vec<Option<FnInfo>> =
        (0..n).map(|i| in_scope[i].then(|| extract(model, i))).collect();
    let resolver = Resolver::build(model, &in_scope);

    let mut visited = vec![false; n];
    let mut parent: Vec<Option<usize>> = vec![None; n];
    let mut queue: VecDeque<usize> = VecDeque::new();
    for i in 0..n {
        if !in_scope[i] {
            continue;
        }
        let f = &model.fns[i];
        if is_serve_request_path(&model.files[f.file].path)
            && (ENTRY_NAMES.contains(&f.name.as_str()) || f.name.starts_with("handle_"))
        {
            visited[i] = true;
            queue.push_back(i);
        }
    }

    while let Some(i) = queue.pop_front() {
        let Some(info) = &infos[i] else { continue };
        for c in &info.calls {
            for t in resolver.resolve(c, i) {
                if !visited[t] {
                    visited[t] = true;
                    parent[t] = Some(i);
                    queue.push_back(t);
                }
            }
        }
    }

    // One finding per (file, line): two unwraps on a line need one fix.
    let mut seen: BTreeSet<(usize, usize)> = BTreeSet::new();
    for i in 0..n {
        if !visited[i] {
            continue;
        }
        let Some(info) = &infos[i] else { continue };
        let f = &model.fns[i];
        let file = &model.files[f.file];
        let serve = is_serve_request_path(&file.path);
        let index_surface = is_index_surface(&file.path);
        for s in &info.sites {
            // Kind-scoping vs the intra-file rules: PANIC-UNWRAP owns
            // unwrap/expect/panic! inside the serve request path, so
            // only Index fires there (and only on the parsing surface);
            // elsewhere Index stays quiet (slice math in fitter cores
            // is bounds-reasoned per kernel) and the rest fires.
            let keep = match s.kind {
                PanicKind::Index => index_surface,
                _ => !serve,
            };
            if !keep {
                continue;
            }
            let line = line_at(&file.code, s.off);
            if !seen.insert((f.file, line)) {
                continue;
            }
            let what = match s.kind {
                PanicKind::Macro => "panic!-family macro",
                PanicKind::Unwrap => "`.unwrap()`",
                PanicKind::Expect => "`.expect()`",
                PanicKind::Index => "unchecked index/slice expression",
            };
            let chain = chain_of(model, &parent, i);
            out.push(Finding {
                path: file.path.clone(),
                line,
                rule: "PANIC-REACH",
                severity: Severity::Error,
                message: format!(
                    "{what} reachable from serve entry via {chain} — return a typed \
                     error, shield with catch_unwind, or allow-mark the line with the \
                     invariant that rules the panic out"
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::scan;

    fn model(files: &[(&str, &str)]) -> CrateModel {
        let mut m = CrateModel::default();
        for (p, src) in files {
            m.add_file(p.to_string(), scan(src));
        }
        m
    }

    fn run(files: &[(&str, &str)]) -> Vec<(String, usize)> {
        let m = model(files);
        let mut out = Vec::new();
        panic_reach(&m, &mut out);
        out.iter().map(|f| (f.path.clone(), f.line)).collect()
    }

    #[test]
    fn unwrap_two_hops_from_entry_fires_and_dead_code_does_not() {
        let serve = "pub fn route(req: &str) -> String {\n    dispatch(req)\n}\nfn dispatch(req: &str) -> String {\n    crate::fit::run_fit(req.len())\n}\n";
        let fit = "pub fn run_fit(t: usize) -> String {\n    helper(t)\n}\nfn helper(t: usize) -> String {\n    let v: Vec<String> = Vec::new();\n    v.first().unwrap().clone()\n}\nfn orphan() {\n    let v: Vec<u32> = Vec::new();\n    v.first().unwrap();\n}\n";
        let got = run(&[
            ("rust/src/serve/http.rs", serve),
            ("rust/src/fit/mod.rs", fit),
        ]);
        assert_eq!(got, vec![("rust/src/fit/mod.rs".to_string(), 6)], "{got:?}");
    }

    #[test]
    fn catch_unwind_shields_both_sites_and_call_edges() {
        let serve = "pub fn handle_fit(req: &str) -> String {\n    let r = std::panic::catch_unwind(|| crate::fit::scary(req.len()));\n    match r { Ok(s) => s, Err(_) => String::new() }\n}\n";
        let fit = "pub fn scary(t: usize) -> String {\n    panic!(\"boom {t}\")\n}\n";
        let got = run(&[
            ("rust/src/serve/http.rs", serve),
            ("rust/src/fit/mod.rs", fit),
        ]);
        assert!(got.is_empty(), "shielded call edge must not mark scary reachable: {got:?}");
    }

    #[test]
    fn serve_unwrap_left_to_panic_unwrap_but_parsing_index_fires() {
        // The unwrap on line 2 is PANIC-UNWRAP's finding, not ours; the
        // slice on line 3 is the Index surface.
        let http = "pub fn route(req: &str) -> String {\n    let n: usize = req.len().checked_sub(1).unwrap();\n    req[..n].to_string()\n}\n";
        let got = run(&[("rust/src/serve/http.rs", http)]);
        assert_eq!(got, vec![("rust/src/serve/http.rs".to_string(), 3)], "{got:?}");
    }

    #[test]
    fn self_and_qualified_method_resolution() {
        let http = "pub struct Engine { t: usize }\nimpl Engine {\n    pub fn handle_req(&self) -> usize {\n        self.inner_step()\n    }\n    fn inner_step(&self) -> usize {\n        crate::kern::Gram::build(self.t)\n    }\n}\n";
        let kern = "pub struct Gram;\nimpl Gram {\n    pub fn build(t: usize) -> usize {\n        t.checked_mul(2).expect(\"overflow\")\n    }\n}\n";
        let got = run(&[
            ("rust/src/serve/engine.rs", http),
            ("rust/src/kern/gram.rs", kern),
        ]);
        assert_eq!(got, vec![("rust/src/kern/gram.rs".to_string(), 4)], "{got:?}");
    }

    #[test]
    fn test_fns_are_neither_entries_nor_targets() {
        let http = "pub fn route(req: &str) -> usize {\n    req.len()\n}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        crate::fit::only_from_test();\n    }\n}\n";
        let fit = "pub fn only_from_test() {\n    panic!(\"never in prod\");\n}\n";
        let got = run(&[
            ("rust/src/serve/http.rs", http),
            ("rust/src/fit/mod.rs", fit),
        ]);
        assert!(got.is_empty(), "{got:?}");
    }
}
