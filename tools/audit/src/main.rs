//! `calars-audit` standalone binary. All logic lives in the library so
//! the `calars audit` CLI subcommand shares it byte-for-byte.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(calars_audit::run_cli(&args));
}
