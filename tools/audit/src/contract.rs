//! ERR-MAP and UNSAFE-BUDGET: the contract-drift rules.
//!
//! ERR-MAP pins three documented surfaces to the code that ships them:
//! every `ErrorKind` variant must have an HTTP status mapping in
//! `serve/http.rs` (a variant nothing maps is a 500 waiting to
//! happen), every route string literal in the serve protocol layer
//! must appear in `docs/API.md`, and every `calars_*` metric name
//! registered anywhere in `rust/src` must be documented there too.
//! The checks are anchored: a tree without `rust/src/error.rs` or
//! without `docs/API.md` (the rule fixtures) vacuously passes the
//! corresponding sub-check instead of drowning in noise.
//!
//! UNSAFE-BUDGET enforces the checked-in unsafe ledger
//! (`tools/audit/unsafe.ledger`): one `path count` line per file in
//! the two sanctioned unsafe regions (`rust/src/par/`,
//! `rust/src/kern/simd/`).  Growth past the recorded count fails the
//! audit at the first over-budget `unsafe` keyword until the ledger is
//! deliberately regenerated with `--update-unsafe-ledger`; a count
//! that fell (or a stale entry) is a warning prompting a regenerate to
//! tighten the budget.

use crate::parse::{line_at, CrateModel};
use crate::rules::{word_occurrences, Finding, Severity};
use std::collections::{BTreeMap, BTreeSet};

/// Repo-relative location of the unsafe ledger.
pub const LEDGER_PATH: &str = "tools/audit/unsafe.ledger";

fn error(path: &str, line: usize, rule: &'static str, message: String) -> Finding {
    Finding { path: path.to_string(), line, rule, severity: Severity::Error, message }
}

fn warning(path: &str, line: usize, rule: &'static str, message: String) -> Finding {
    Finding { path: path.to_string(), line, rule, severity: Severity::Warning, message }
}

/// Is `text` shaped like a served route (`/fit`, `/fit/batch`)?
fn looks_like_route(text: &str) -> bool {
    let t = text.trim_end_matches('/');
    let b = t.as_bytes();
    t.len() >= 2
        && b[0] == b'/'
        && b[1].is_ascii_lowercase()
        && b[1..]
            .iter()
            .all(|&c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == b'_' || c == b'/')
}

/// Leading `[a-z0-9_]+` run of a metric-name literal.
fn metric_name(text: &str) -> &str {
    let end = text
        .bytes()
        .position(|c| !(c.is_ascii_lowercase() || c.is_ascii_digit() || c == b'_'))
        .unwrap_or(text.len());
    &text[..end]
}

/// The ERR-MAP pass.  `api_md` is the contents of `docs/API.md` when
/// it exists; without it the route/metric sub-checks are vacuous.
pub fn err_map(model: &CrateModel, api_md: Option<&str>, out: &mut Vec<Finding>) {
    // (a) ErrorKind variants ↔ HTTP status mapping in serve/http.rs.
    let kinds = model.enums.iter().find(|e| {
        e.name == "ErrorKind" && model.files[e.file].path == "rust/src/error.rs"
    });
    let http = model.files.iter().find(|f| f.path == "rust/src/serve/http.rs");
    if let (Some(kinds), Some(http)) = (kinds, http) {
        let epath = model.files[kinds.file].path.clone();
        for (variant, line) in &kinds.variants {
            let needle = format!("ErrorKind::{variant}");
            let mapped = word_occurrences(&http.code, &needle).iter().any(|&off| {
                !http.scan.is_test_line(line_at(&http.code, off))
            });
            if !mapped {
                out.push(error(
                    &epath,
                    *line,
                    "ERR-MAP",
                    format!(
                        "ErrorKind::{variant} has no HTTP status mapping in \
                         rust/src/serve/http.rs — every error kind a fit can return \
                         must map to a status (see error_status)"
                    ),
                ));
            }
        }
    }

    let Some(api) = api_md else { return };

    // (b) Route literals on the serve protocol surface ↔ docs/API.md.
    let mut seen_routes: BTreeSet<String> = BTreeSet::new();
    for file in &model.files {
        if file.path != "rust/src/serve/http.rs"
            && file.path != "rust/src/serve/protocol.rs"
        {
            continue;
        }
        for lit in &file.scan.strs {
            if file.scan.is_test_line(lit.line) || !looks_like_route(&lit.text) {
                continue;
            }
            let route = lit.text.trim_end_matches('/').to_string();
            if !seen_routes.insert(route.clone()) {
                continue;
            }
            if !api.contains(&route) {
                out.push(error(
                    &file.path,
                    lit.line,
                    "ERR-MAP",
                    format!(
                        "route \"{route}\" is served but not documented in \
                         docs/API.md — document it (or rename the literal if it is \
                         not a route)"
                    ),
                ));
            }
        }
    }

    // (c) Registered metric names ↔ docs/API.md.
    let mut seen_metrics: BTreeSet<String> = BTreeSet::new();
    for file in &model.files {
        if !file.path.starts_with("rust/src/") {
            continue;
        }
        for lit in &file.scan.strs {
            if file.scan.is_test_line(lit.line) || !lit.text.starts_with("calars_") {
                continue;
            }
            let name = metric_name(&lit.text).to_string();
            if name.len() <= "calars_".len() || !seen_metrics.insert(name.clone()) {
                continue;
            }
            if !api.contains(&name) {
                out.push(error(
                    &file.path,
                    lit.line,
                    "ERR-MAP",
                    format!(
                        "metric \"{name}\" is registered but not documented in \
                         docs/API.md — the /metrics surface is part of the API \
                         contract"
                    ),
                ));
            }
        }
    }
}

/// Is this file inside a sanctioned unsafe region?
fn in_unsafe_scope(path: &str) -> bool {
    path.starts_with("rust/src/par/") || path.starts_with("rust/src/kern/simd/")
}

/// 1-based lines of every non-test `unsafe` keyword per in-scope file.
fn unsafe_sites(model: &CrateModel) -> BTreeMap<String, Vec<usize>> {
    let mut out: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for file in &model.files {
        if !in_unsafe_scope(&file.path) {
            continue;
        }
        let lines: Vec<usize> = word_occurrences(&file.code, "unsafe")
            .into_iter()
            .map(|off| line_at(&file.code, off))
            .filter(|&l| !file.scan.is_test_line(l))
            .collect();
        if !lines.is_empty() {
            out.insert(file.path.clone(), lines);
        }
    }
    out
}

/// Regenerate the ledger contents (deterministic, sorted by path).
pub fn ledger_text(model: &CrateModel) -> String {
    let mut out = String::from(
        "# unsafe budget — one `path count` per file in the sanctioned unsafe\n\
         # regions (rust/src/par/, rust/src/kern/simd/).  Regenerate with\n\
         # `calars audit --update-unsafe-ledger` after reviewing every new block.\n",
    );
    for (path, sites) in unsafe_sites(model) {
        out.push_str(&format!("{} {}\n", path, sites.len()));
    }
    out
}

/// The UNSAFE-BUDGET pass.  `ledger` is the contents of
/// [`LEDGER_PATH`] when the file exists.
pub fn unsafe_budget(model: &CrateModel, ledger: Option<&str>, out: &mut Vec<Finding>) {
    let sites = unsafe_sites(model);
    let Some(ledger) = ledger else {
        for (path, lines) in &sites {
            out.push(error(
                path,
                lines[0],
                "UNSAFE-BUDGET",
                format!(
                    "{} unsafe block(s) but no ledger at {LEDGER_PATH} — review them \
                     and check the ledger in with --update-unsafe-ledger",
                    lines.len()
                ),
            ));
        }
        return;
    };

    let mut entries: BTreeMap<&str, (usize, usize)> = BTreeMap::new(); // path → (count, ledger line)
    for (idx, raw) in ledger.lines().enumerate() {
        let line = idx + 1;
        let l = raw.trim();
        if l.is_empty() || l.starts_with('#') {
            continue;
        }
        let mut it = l.split_whitespace();
        let (Some(path), Some(count), None) = (it.next(), it.next(), it.next()) else {
            out.push(error(
                LEDGER_PATH,
                line,
                "UNSAFE-BUDGET",
                format!("malformed ledger line `{l}` — expected `path count`"),
            ));
            continue;
        };
        let Ok(count) = count.parse::<usize>() else {
            out.push(error(
                LEDGER_PATH,
                line,
                "UNSAFE-BUDGET",
                format!("malformed ledger count in `{l}` — expected `path count`"),
            ));
            continue;
        };
        entries.insert(path, (count, line));
    }

    for (path, lines) in &sites {
        match entries.get(path.as_str()) {
            None => out.push(error(
                path,
                lines[0],
                "UNSAFE-BUDGET",
                format!(
                    "{} unsafe block(s) but no entry in {LEDGER_PATH} — review them \
                     and regenerate with --update-unsafe-ledger",
                    lines.len()
                ),
            )),
            Some(&(count, lline)) => {
                if lines.len() > count {
                    out.push(error(
                        path,
                        lines[count],
                        "UNSAFE-BUDGET",
                        format!(
                            "unsafe count grew from {count} (ledgered) to {} — \
                             justify the new block(s) and regenerate with \
                             --update-unsafe-ledger",
                            lines.len()
                        ),
                    ));
                } else if lines.len() < count {
                    out.push(warning(
                        LEDGER_PATH,
                        lline,
                        "UNSAFE-BUDGET",
                        format!(
                            "{path} ledgered at {count} but now has {} unsafe \
                             block(s) — regenerate to tighten the budget",
                            lines.len()
                        ),
                    ));
                }
            }
        }
    }
    for (path, &(_, lline)) in &entries {
        if !sites.contains_key(*path) {
            out.push(warning(
                LEDGER_PATH,
                lline,
                "UNSAFE-BUDGET",
                format!(
                    "stale ledger entry for {path} — the file has no unsafe blocks \
                     (or no longer exists); regenerate to drop it"
                ),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::scan;

    fn model(files: &[(&str, &str)]) -> CrateModel {
        let mut m = CrateModel::default();
        for (p, src) in files {
            m.add_file(p.to_string(), scan(src));
        }
        m
    }

    #[test]
    fn unmapped_error_kind_variant_fires_at_the_variant_line() {
        let m = model(&[
            (
                "rust/src/error.rs",
                "pub enum ErrorKind {\n    Other,\n    Orphaned,\n}\n",
            ),
            (
                "rust/src/serve/http.rs",
                "pub fn error_status(k: &crate::error::ErrorKind) -> u16 {\n    match k {\n        crate::error::ErrorKind::Other => 500,\n        _ => 500,\n    }\n}\n",
            ),
        ]);
        let mut out = Vec::new();
        err_map(&m, None, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!((out[0].path.as_str(), out[0].line), ("rust/src/error.rs", 3));
        assert!(out[0].message.contains("Orphaned"), "{}", out[0].message);
    }

    #[test]
    fn undocumented_route_and_metric_fire_only_with_api_docs_present() {
        let files = [
            (
                "rust/src/serve/protocol.rs",
                "pub fn routes() -> [&'static str; 2] {\n    [\"/fit\", \"/undocumented\"]\n}\n",
            ),
            (
                "rust/src/obs/metrics.rs",
                "pub fn names() -> [&'static str; 2] {\n    [\"calars_fit_total\", \"calars_ghost_total\"]\n}\n",
            ),
        ];
        let m = model(&files);
        let mut out = Vec::new();
        err_map(&m, None, &mut out);
        assert!(out.is_empty(), "no docs/API.md → vacuous: {out:?}");
        let api = "## Routes\n`/fit` …\n## Metrics\n`calars_fit_total` …\n";
        err_map(&m, Some(api), &mut out);
        let got: Vec<(&str, usize)> =
            out.iter().map(|f| (f.path.as_str(), f.line)).collect();
        assert_eq!(
            got,
            vec![("rust/src/serve/protocol.rs", 2), ("rust/src/obs/metrics.rs", 2)],
            "{out:?}"
        );
        assert!(out[0].message.contains("/undocumented"));
        assert!(out[1].message.contains("calars_ghost_total"));
    }

    #[test]
    fn unsafe_budget_over_under_and_stale() {
        let m = model(&[
            (
                "rust/src/par/raw.rs",
                "pub fn f() {\n    unsafe { a() }\n    unsafe { b() }\n}\n",
            ),
            ("rust/src/kern/simd/ok.rs", "pub fn g() {\n    unsafe { c() }\n}\n"),
        ]);
        // Over budget: raw.rs ledgered at 1, has 2 → error at 2nd site.
        let ledger = "# comment\nrust/src/par/raw.rs 1\nrust/src/kern/simd/ok.rs 1\nrust/src/par/gone.rs 3\n";
        let mut out = Vec::new();
        unsafe_budget(&m, Some(ledger), &mut out);
        let got: Vec<(&str, usize, bool)> = out
            .iter()
            .map(|f| (f.path.as_str(), f.line, f.severity == Severity::Error))
            .collect();
        assert_eq!(
            got,
            vec![
                ("rust/src/par/raw.rs", 3, true),
                ("tools/audit/unsafe.ledger", 4, false),
            ],
            "{out:?}"
        );
    }

    #[test]
    fn missing_ledger_with_unsafe_is_an_error_and_matching_ledger_is_clean() {
        let m = model(&[(
            "rust/src/kern/simd/ok.rs",
            "pub fn g() {\n    unsafe { c() }\n}\n",
        )]);
        let mut out = Vec::new();
        unsafe_budget(&m, None, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].line, 2);
        out.clear();
        unsafe_budget(&m, Some(&ledger_text(&m)), &mut out);
        assert!(out.is_empty(), "{out:?}");
        assert!(ledger_text(&m).contains("rust/src/kern/simd/ok.rs 1"));
    }

    #[test]
    fn test_only_unsafe_and_out_of_scope_files_do_not_count() {
        let m = model(&[
            (
                "rust/src/kern/evil.rs",
                "pub fn h() {\n    unsafe { d() }\n}\n",
            ),
            (
                "rust/src/par/t.rs",
                "#[cfg(test)]\nmod tests {\n    fn t() {\n        unsafe { e() }\n    }\n}\n",
            ),
        ]);
        let mut out = Vec::new();
        unsafe_budget(&m, None, &mut out);
        assert!(out.is_empty(), "kern (non-simd) and cfg(test) are out of scope: {out:?}");
    }
}
