//! calars-audit: the project's own static-analysis pass.
//!
//! Walks the calars source tree and enforces the contracts no compiler
//! checks — determinism (one canonical summation order, total
//! comparators, no unordered hash iteration in hot paths, no hidden
//! clock/RNG inputs in fitter cores), panic safety in the serve layer
//! (typed errors, poison-recovering locks), the unsafe budget (par
//! only, every block documented), and the zero-dependency workspace.
//! See DESIGN.md §"Static analysis & invariants" for the rationale
//! behind each rule; `calars audit --explain <RULE>` prints the same
//! argument at the terminal.
//!
//! The pass is deliberately a *scanner*, not a compiler plugin: a
//! hand-rolled lexer ([`lexer`]) separates code from comments and
//! blanks literals, and the rules ([`rules`]) are ASCII pattern
//! matchers over the blanked code. That keeps the tool zero-dep and
//! fast (one pass over ~15k lines), at the price of being heuristic —
//! which is exactly what the reasoned `// audit: allow(RULE) -- why`
//! escape hatch is for.

pub mod callgraph;
pub mod contract;
pub mod lexer;
pub mod locks;
pub mod manifest;
pub mod parse;
pub mod rules;

use rules::{AllowMarker, FileCtx, Finding, Severity};
use std::path::{Path, PathBuf};

/// What to audit. [`Config::default`] matches CI: the real walk set,
/// warnings allowed. Fixture tests swap in miniature trees.
#[derive(Debug, Clone)]
pub struct Config {
    /// Root-relative directories to walk for `.rs` files.
    pub walk_dirs: Vec<String>,
    /// Promote warnings (ALLOW-UNUSED) to failures.
    pub deny_warnings: bool,
    /// Regenerate tools/audit/unsafe.ledger from the tree instead of
    /// checking against it.
    pub update_unsafe_ledger: bool,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            walk_dirs: vec![
                "rust/src".to_string(),
                "rust/tests".to_string(),
                "benches".to_string(),
            ],
            deny_warnings: false,
            update_unsafe_ledger: false,
        }
    }
}

/// The outcome of one audit run.
#[derive(Debug, Default)]
pub struct Report {
    /// Unsuppressed findings, sorted by (path, line, rule).
    pub findings: Vec<Finding>,
    /// Findings suppressed by reasoned allow markers.
    pub suppressed: usize,
    /// `.rs` files scanned.
    pub files_scanned: usize,
    /// Manifests checked for DEP-EXT.
    pub manifests_checked: usize,
}

impl Report {
    pub fn errors(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Warning).count()
    }

    /// Does this report pass under the given policy?
    pub fn is_clean(&self, deny_warnings: bool) -> bool {
        self.errors() == 0 && (!deny_warnings || self.warnings() == 0)
    }

    /// Human-readable diagnostics, one `file:line: severity[RULE]:
    /// message` per finding, plus a summary line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            let sev = match f.severity {
                Severity::Error => "error",
                Severity::Warning => "warning",
            };
            out.push_str(&format!(
                "{}:{}: {}[{}]: {}\n",
                f.path, f.line, sev, f.rule, f.message
            ));
        }
        out.push_str(&format!(
            "audit: {} error(s), {} warning(s), {} finding(s) suppressed by allow \
             markers, {} file(s) + {} manifest(s) checked\n",
            self.errors(),
            self.warnings(),
            self.suppressed,
            self.files_scanned,
            self.manifests_checked,
        ));
        out
    }

    /// Machine-readable diagnostics (`calars audit --json`): one JSON
    /// object, hand-serialized under the zero-dep contract.
    pub fn render_json(&self) -> String {
        let mut out = String::from("{\"findings\":[");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let sev = match f.severity {
                Severity::Error => "error",
                Severity::Warning => "warning",
            };
            out.push_str(&format!(
                "{{\"path\":\"{}\",\"line\":{},\"rule\":\"{}\",\"severity\":\"{sev}\",\
                 \"message\":\"{}\"}}",
                json_escape(&f.path),
                f.line,
                json_escape(f.rule),
                json_escape(&f.message),
            ));
        }
        out.push_str(&format!(
            "],\"errors\":{},\"warnings\":{},\"suppressed\":{},\"files_scanned\":{},\
             \"manifests_checked\":{}}}\n",
            self.errors(),
            self.warnings(),
            self.suppressed,
            self.files_scanned,
            self.manifests_checked,
        ));
        out
    }

    /// GitHub Actions workflow-command annotations, one per finding,
    /// so CI failures land inline on the PR diff.
    pub fn render_github(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            let cmd = match f.severity {
                Severity::Error => "error",
                Severity::Warning => "warning",
            };
            out.push_str(&format!(
                "::{cmd} file={},line={},title={}::{}\n",
                gh_property(&f.path),
                f.line,
                gh_property(f.rule),
                gh_data(&f.message),
            ));
        }
        out
    }
}

/// Minimal JSON string escaping (quotes, backslash, control chars).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Escape the data part of a GitHub workflow command.
fn gh_data(s: &str) -> String {
    s.replace('%', "%25").replace('\r', "%0D").replace('\n', "%0A")
}

/// Escape a property value of a GitHub workflow command.
fn gh_property(s: &str) -> String {
    gh_data(s).replace(':', "%3A").replace(',', "%2C")
}

/// Recursively collect `.rs` files under `dir`, sorted for
/// deterministic output.
fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs_files(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Repo-relative forward-slash path for diagnostics.
fn rel_path(root: &Path, p: &Path) -> String {
    let rel = p.strip_prefix(root).unwrap_or(p);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

/// Run the full audit over `root` with `cfg`: pass 1 scans each file
/// (token rules + allow markers) and feeds it into the crate model;
/// pass 2 runs the interprocedural rules over the completed model.
pub fn run_audit(root: &Path, cfg: &Config) -> std::io::Result<Report> {
    let mut findings: Vec<Finding> = Vec::new();
    let mut markers: Vec<AllowMarker> = Vec::new();
    let mut report = Report::default();
    let mut model = parse::CrateModel::default();

    for dir in &cfg.walk_dirs {
        let abs = root.join(dir);
        if !abs.is_dir() {
            continue;
        }
        let mut files = Vec::new();
        collect_rs_files(&abs, &mut files)?;
        for file in files {
            let src = std::fs::read_to_string(&file)?;
            let scan = lexer::scan(&src);
            let path = rel_path(root, &file);
            let ctx = FileCtx { path: &path, scan: &scan };
            rules::check_file(&ctx, &mut findings);
            markers.extend(rules::collect_markers(&path, &scan));
            report.files_scanned += 1;
            model.add_file(path, scan);
        }
    }

    // Pass 2: the interprocedural rule families over the whole model.
    // Runs before apply_markers so allow markers can suppress these
    // findings exactly like the token rules'.
    callgraph::panic_reach(&model, &mut findings);
    locks::lock_order(&model, &mut findings);
    let api_md = std::fs::read_to_string(root.join("docs/API.md")).ok();
    contract::err_map(&model, api_md.as_deref(), &mut findings);
    let ledger = if cfg.update_unsafe_ledger {
        let text = contract::ledger_text(&model);
        std::fs::write(root.join(contract::LEDGER_PATH), &text)?;
        Some(text)
    } else {
        std::fs::read_to_string(root.join(contract::LEDGER_PATH)).ok()
    };
    contract::unsafe_budget(&model, ledger.as_deref(), &mut findings);

    // DEP-EXT over the root manifest and every workspace member's.
    let root_toml_path = root.join("Cargo.toml");
    if let Ok(root_toml) = std::fs::read_to_string(&root_toml_path) {
        manifest::check_manifest("Cargo.toml", &root_toml, &mut findings);
        report.manifests_checked += 1;
        for member in manifest::workspace_members(&root_toml) {
            let member_toml = root.join(&member).join("Cargo.toml");
            if let Ok(toml) = std::fs::read_to_string(&member_toml) {
                manifest::check_manifest(
                    &format!("{member}/Cargo.toml"),
                    &toml,
                    &mut findings,
                );
                report.manifests_checked += 1;
            }
        }
    }

    let (mut kept, suppressed) = rules::apply_markers(findings, &mut markers);
    kept.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    report.findings = kept;
    report.suppressed = suppressed;
    Ok(report)
}

/// Walk up from `start` to the workspace root (the directory whose
/// `Cargo.toml` declares `[workspace]`).
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut dir = start.to_path_buf();
    loop {
        let toml = dir.join("Cargo.toml");
        if let Ok(s) = std::fs::read_to_string(&toml) {
            if s.contains("[workspace]") {
                return Some(dir);
            }
        }
        if !dir.pop() {
            return None;
        }
    }
}

const USAGE: &str = "\
calars-audit — static-analysis pass for the calars contracts

USAGE:
    calars-audit [--root DIR] [--deny-warnings] [--json | --github]
                 [--update-unsafe-ledger]
    calars-audit --explain RULE
    calars-audit --list

OPTIONS:
    --root DIR        workspace root (default: discovered from the cwd)
    --deny-warnings   treat warnings (ALLOW-UNUSED, budget drift) as
                      failures (CI mode)
    --json            machine-readable report on stdout instead of text
    --github          text report plus GitHub Actions ::error/::warning
                      annotations (inline PR findings in CI)
    --update-unsafe-ledger
                      regenerate tools/audit/unsafe.ledger from the tree
                      (UNSAFE-BUDGET then checks against the fresh copy)
    --explain RULE    print the invariant behind a rule id and exit
    --list            list every rule id with a one-line summary

EXIT CODES:
    0  clean (no errors; no warnings under --deny-warnings)
    1  findings reported
    2  usage error
";

/// The CLI entry point shared by the `calars-audit` binary and the
/// `calars audit` subcommand. Returns the process exit code.
pub fn run_cli(args: &[String]) -> i32 {
    let mut root_arg: Option<String> = None;
    let mut deny_warnings = false;
    let mut json = false;
    let mut github = false;
    let mut update_unsafe_ledger = false;
    let mut explain: Option<String> = None;
    let mut list = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--root" => {
                i += 1;
                let Some(v) = args.get(i) else {
                    eprintln!("error: --root needs a directory\n\n{USAGE}");
                    return 2;
                };
                root_arg = Some(v.clone());
            }
            "--deny-warnings" => deny_warnings = true,
            "--json" => json = true,
            "--github" => github = true,
            "--update-unsafe-ledger" => update_unsafe_ledger = true,
            "--explain" => {
                i += 1;
                let Some(v) = args.get(i) else {
                    eprintln!("error: --explain needs a rule id\n\n{USAGE}");
                    return 2;
                };
                explain = Some(v.clone());
            }
            "--list" => list = true,
            "--help" | "-h" => {
                print!("{USAGE}");
                return 0;
            }
            other => {
                eprintln!("error: unknown argument `{other}`\n\n{USAGE}");
                return 2;
            }
        }
        i += 1;
    }

    if list {
        for r in rules::RULES {
            println!("{:<14} {}", r.id, r.summary);
        }
        return 0;
    }
    if let Some(id) = explain {
        let Some(doc) = rules::rule_doc(&id) else {
            eprintln!("error: unknown rule `{id}` — known rules:");
            for r in rules::RULES {
                eprintln!("  {:<14} {}", r.id, r.summary);
            }
            return 2;
        };
        println!("{} — {}\n\n{}", doc.id, doc.summary, doc.explain);
        return 0;
    }

    let root = match root_arg {
        Some(r) => PathBuf::from(r),
        None => {
            let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
            match find_root(&cwd) {
                Some(r) => r,
                None => {
                    eprintln!(
                        "error: no workspace root found above {} (pass --root DIR)",
                        cwd.display()
                    );
                    return 2;
                }
            }
        }
    };
    if !root.is_dir() {
        eprintln!("error: --root {} is not a directory", root.display());
        return 2;
    }

    if json && github {
        eprintln!("error: --json and --github are mutually exclusive\n\n{USAGE}");
        return 2;
    }

    let cfg = Config { deny_warnings, update_unsafe_ledger, ..Config::default() };
    match run_audit(&root, &cfg) {
        Ok(report) => {
            if json {
                print!("{}", report.render_json());
            } else {
                print!("{}", report.render());
                if github {
                    print!("{}", report.render_github());
                }
            }
            if report.is_clean(cfg.deny_warnings) {
                0
            } else {
                1
            }
        }
        Err(e) => {
            eprintln!("error: audit walk failed: {e}");
            2
        }
    }
}
