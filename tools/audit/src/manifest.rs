//! DEP-EXT: the zero-dependency guard.
//!
//! A minimal, purpose-built Cargo.toml reader — not a TOML parser. It
//! understands exactly the shapes this workspace uses: the root
//! `[workspace] members = [...]` array and flat
//! `[dependencies]`-family sections whose entries are either
//! `name = "1.0"` (external — a finding) or inline tables
//! (`name = { path = "..." }` is in-workspace and allowed;
//! `version`/`git`/`registry` keys make it external).

use crate::rules::{Finding, Severity};

/// Strip a `#` comment (outside string literals) and trailing space.
fn strip_comment(line: &str) -> &str {
    let bytes = line.as_bytes();
    let mut in_str = false;
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'"' => in_str = !in_str,
            b'#' if !in_str => return line[..i].trim_end(),
            _ => {}
        }
    }
    line.trim_end()
}

/// Parse `members = [...]` from the root manifest (single- or
/// multi-line arrays).
pub fn workspace_members(root_toml: &str) -> Vec<String> {
    let mut members = Vec::new();
    let mut in_workspace = false;
    let mut in_members = false;
    for raw in root_toml.lines() {
        let line = strip_comment(raw).trim();
        if line.starts_with('[') {
            in_workspace = line == "[workspace]";
            in_members = false;
            continue;
        }
        if in_workspace && line.starts_with("members") {
            in_members = true;
        }
        if in_members {
            for piece in line.split('"').skip(1).step_by(2) {
                members.push(piece.to_string());
            }
            if line.contains(']') {
                in_members = false;
            }
        }
    }
    members
}

/// Check one member manifest for external dependencies.
///
/// `path` is the repo-relative manifest path used in diagnostics.
pub fn check_manifest(path: &str, toml: &str, out: &mut Vec<Finding>) {
    const DEP_SECTIONS: [&str; 3] =
        ["[dependencies]", "[dev-dependencies]", "[build-dependencies]"];
    let mut in_deps = false;
    // Open `[dependencies.name]`-style table: (name, header line,
    // saw path key, saw external key).
    let mut dotted: Option<(String, usize, bool, bool)> = None;
    let mut flush_dotted = |d: &mut Option<(String, usize, bool, bool)>,
                            out: &mut Vec<Finding>| {
        if let Some((name, line, has_path, has_ext)) = d.take() {
            if has_ext || !has_path {
                out.push(Finding {
                    path: path.to_string(),
                    line,
                    rule: "DEP-EXT",
                    severity: Severity::Error,
                    message: format!(
                        "external dependency `{name}`: the workspace is zero-dependency \
                         by contract — vendor the functionality in-tree (only \
                         `path = …` workspace members are allowed)"
                    ),
                });
            }
        }
    };
    for (idx, raw) in toml.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.starts_with('[') {
            flush_dotted(&mut dotted, out);
            in_deps = DEP_SECTIONS.contains(&line);
            if !in_deps {
                for s in DEP_SECTIONS {
                    let dotted_prefix = format!("{}.", &s[..s.len() - 1]);
                    if let Some(rest) = line.strip_prefix(&dotted_prefix) {
                        let name = rest.trim_end_matches(']').to_string();
                        dotted = Some((name, idx + 1, false, false));
                    }
                }
            }
            continue;
        }
        if let Some(d) = dotted.as_mut() {
            if line.starts_with("path") {
                d.2 = true;
            }
            if ["version", "git", "registry", "branch", "rev"]
                .iter()
                .any(|k| line.starts_with(k))
            {
                d.3 = true;
            }
            continue;
        }
        if !in_deps || line.is_empty() {
            continue;
        }
        let Some((name, value)) = line.split_once('=') else { continue };
        let name = name.trim();
        let value = value.trim();
        let external = if value.starts_with('{') {
            // Inline table: path-only members of this workspace are
            // fine; any resolution hint pointing outside is not.
            ["version", "git ", "git=", "registry", "branch", "rev ", "rev="]
                .iter()
                .any(|k| value.contains(k))
                || !value.contains("path")
        } else {
            // `name = "1.0"` — a registry version requirement.
            true
        };
        if external {
            out.push(Finding {
                path: path.to_string(),
                line: idx + 1,
                rule: "DEP-EXT",
                severity: Severity::Error,
                message: format!(
                    "external dependency `{name}`: the workspace is zero-dependency \
                     by contract — vendor the functionality in-tree (only \
                     `path = …` workspace members are allowed)"
                ),
            });
        }
    }
    flush_dotted(&mut dotted, out);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn members_single_and_multi_line() {
        let m = workspace_members("[workspace]\nmembers = [\"rust\", \"tools/audit\"]\n");
        assert_eq!(m, vec!["rust".to_string(), "tools/audit".to_string()]);
        let m2 = workspace_members(
            "[workspace]\nmembers = [\n    \"rust\", # core\n    \"tools/audit\",\n]\n",
        );
        assert_eq!(m2, vec!["rust".to_string(), "tools/audit".to_string()]);
    }

    #[test]
    fn registry_dep_is_flagged_path_dep_is_not() {
        let mut out = Vec::new();
        check_manifest(
            "rust/Cargo.toml",
            "[package]\nname = \"calars\"\n\n[dependencies]\nserde = \"1.0\"\ncalars-audit = { path = \"../tools/audit\" }\n",
            &mut out,
        );
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].rule, "DEP-EXT");
        assert_eq!(out[0].line, 5);
        assert!(out[0].message.contains("serde"));
    }

    #[test]
    fn git_and_versioned_tables_are_flagged() {
        let mut out = Vec::new();
        check_manifest(
            "x/Cargo.toml",
            "[dev-dependencies]\nfoo = { git = \"https://example.com/foo\" }\nbar = { path = \"../bar\", version = \"0.1\" }\n",
            &mut out,
        );
        assert_eq!(out.len(), 2, "{out:?}");
    }

    #[test]
    fn dotted_dep_tables_are_checked() {
        let mut out = Vec::new();
        check_manifest(
            "x/Cargo.toml",
            "[dependencies.rayon]\nversion = \"1.8\"\n\n[dependencies.local]\npath = \"../local\"\n",
            &mut out,
        );
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].message.contains("rayon"));
        assert_eq!(out[0].line, 1);
    }

    #[test]
    fn empty_sections_and_comments_are_fine() {
        let mut out = Vec::new();
        check_manifest(
            "tools/audit/Cargo.toml",
            "[package]\nname = \"calars-audit\"\n\n[dependencies]\n# none, by design\n\n[lib]\nname = \"calars_audit\"\n",
            &mut out,
        );
        assert!(out.is_empty(), "{out:?}");
    }
}
