//! A hand-rolled Rust *item* parser over the blanked code stream:
//! builds the per-crate symbol table the interprocedural rules walk.
//!
//! This is deliberately an approximation, not a compiler front-end: a
//! scope stack driven by brace matching recognizes `fn` / `impl` /
//! `trait` / `struct` / `enum` / `static` items, records function
//! bodies as byte ranges into the blanked [`code
//! text`](crate::lexer::FileScan::code_text), and captures just enough
//! type information (lock-typed struct fields and statics, method
//! qualifiers, parameter types) for the PANIC-REACH call-graph walk
//! and the LOCK-ORDER acquisition-graph extraction. What the
//! approximation can and cannot see is documented in DESIGN.md
//! §"Static analysis & invariants".

use crate::lexer::FileScan;
use std::ops::Range;

/// One function (or method) definition.
#[derive(Debug)]
pub struct FnDef {
    pub name: String,
    /// Enclosing `impl TYPE` / `trait NAME` qualifier, `None` for free
    /// functions.
    pub qual: Option<String>,
    /// Index into [`CrateModel::files`].
    pub file: usize,
    /// 1-based line of the `fn` keyword.
    pub line: usize,
    /// Signature text from `fn` through the body brace (exclusive).
    pub sig: String,
    /// Byte range of the body *contents* in the file's code text
    /// (between the braces); `None` for bodyless trait declarations.
    pub body: Option<Range<usize>>,
    /// Inside a `#[cfg(test)]` region.
    pub is_test: bool,
}

impl FnDef {
    /// `(name, type-text)` for every named, non-`self` parameter.
    pub fn params(&self) -> Vec<(String, String)> {
        let b = self.sig.as_bytes();
        let mut k = 2; // sig always starts with the `fn` keyword
        k = skip_ws_b(b, k);
        while k < b.len() && is_ident_byte(b[k]) {
            k += 1;
        }
        k = skip_ws_b(b, k);
        if k < b.len() && b[k] == b'<' {
            k = skip_angles(b, k);
        }
        k = skip_ws_b(b, k);
        if b.get(k) != Some(&b'(') {
            return Vec::new();
        }
        let Some(close) = match_delim_b(b, k, b'(', b')') else {
            return Vec::new();
        };
        let inner = &self.sig[k + 1..close];
        let mut out = Vec::new();
        for (_, part) in split_top_level(inner, b',') {
            let p = part.trim();
            if p.is_empty() {
                continue;
            }
            // `self` / `&self` / `&mut self` have no type colon.
            let Some(ci) = find_type_colon(p) else { continue };
            let Some(name) = trailing_ident(p[..ci].trim_end()) else {
                continue;
            };
            out.push((name, p[ci + 1..].trim().to_string()));
        }
        out
    }

    /// Does this function hand back a lock guard (the wrapper-function
    /// marker the LOCK-ORDER pass keys on)? Substring check on the
    /// signature — guard types appear in return position only, in this
    /// tree.
    pub fn returns_guard(&self) -> bool {
        ["MutexGuard", "RwLockReadGuard", "RwLockWriteGuard"]
            .iter()
            .any(|g| self.sig.contains(g))
    }
}

/// One named struct field.
#[derive(Debug)]
pub struct FieldDef {
    pub name: String,
    /// Type text, verbatim (trimmed).
    pub ty: String,
}

/// One struct with named fields (tuple/unit structs record no fields).
#[derive(Debug)]
pub struct StructDef {
    pub name: String,
    /// Module path derived from the file path (`par::pool`), used to
    /// disambiguate same-named structs across modules.
    pub module: String,
    pub file: usize,
    pub line: usize,
    pub fields: Vec<FieldDef>,
}

/// One `static NAME: TYPE = …;` item.
#[derive(Debug)]
pub struct StaticDef {
    pub name: String,
    pub ty: String,
    pub file: usize,
    pub line: usize,
}

/// One enum with its variants (ERR-MAP reads `ErrorKind` from here).
#[derive(Debug)]
pub struct EnumDef {
    pub name: String,
    pub file: usize,
    /// `(variant name, 1-based line)`.
    pub variants: Vec<(String, usize)>,
}

/// One scanned + parsed file.
#[derive(Debug, Default)]
pub struct ParsedFile {
    /// Repo-relative forward-slash path.
    pub path: String,
    /// Blanked code text ([`FileScan::code_text`]).
    pub code: String,
    pub scan: FileScan,
    /// Indices into [`CrateModel::fns`] for fns defined here.
    pub fns: Vec<usize>,
}

/// The whole-crate symbol table the global rules walk.
#[derive(Debug, Default)]
pub struct CrateModel {
    pub files: Vec<ParsedFile>,
    pub fns: Vec<FnDef>,
    pub structs: Vec<StructDef>,
    pub statics: Vec<StaticDef>,
    pub enums: Vec<EnumDef>,
}

impl CrateModel {
    /// Scan results move in here; parsing happens immediately so the
    /// global passes only ever see a complete table.
    pub fn add_file(&mut self, path: String, scan: FileScan) {
        let code = scan.code_text();
        self.files.push(ParsedFile { path, code, scan, fns: Vec::new() });
        let idx = self.files.len() - 1;
        parse_file_items(self, idx);
    }

    /// Is `ty` a lock type (the LOCK-ORDER identity test)?
    pub fn is_lock_type(ty: &str) -> bool {
        ty.contains("Mutex<") || ty.contains("RwLock<")
    }
}

pub fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

pub fn skip_ws_b(b: &[u8], mut i: usize) -> usize {
    while i < b.len() && b[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

/// 1-based line of byte offset `off` in `code`.
pub fn line_at(code: &str, off: usize) -> usize {
    let end = off.min(code.len());
    code.as_bytes()[..end].iter().filter(|&&b| b == b'\n').count() + 1
}

fn count_nl(b: &[u8]) -> usize {
    b.iter().filter(|&&c| c == b'\n').count()
}

/// Offset just past the matching closer for the opener at `open`
/// (which must hold `open_b`). `None` when unbalanced.
pub fn match_delim_b(b: &[u8], open: usize, open_b: u8, close_b: u8) -> Option<usize> {
    let mut depth = 0usize;
    let mut i = open;
    while i < b.len() {
        let c = b[i];
        if c == open_b {
            depth += 1;
        } else if c == close_b {
            depth = depth.checked_sub(1)?;
            if depth == 0 {
                return Some(i);
            }
        }
        i += 1;
    }
    None
}

/// Skip a balanced `<…>` group starting at `i` (which holds `<`),
/// treating the `>` of `->` as plain text. Returns the offset just
/// past the closing `>`.
pub(crate) fn skip_angles(b: &[u8], mut i: usize) -> usize {
    let mut depth = 0i64;
    while i < b.len() {
        match b[i] {
            b'<' => depth += 1,
            b'>' if i > 0 && b[i - 1] == b'-' => {}
            b'>' => {
                depth -= 1;
                if depth <= 0 {
                    return i + 1;
                }
            }
            _ => {}
        }
        i += 1;
    }
    i
}

/// Split `s` on `sep` at bracket depth zero (tracking `()[]{}<>`, with
/// the `>` of `->` treated as text). Returns `(offset, piece)` pairs.
pub fn split_top_level(s: &str, sep: u8) -> Vec<(usize, &str)> {
    let b = s.as_bytes();
    let mut out = Vec::new();
    let mut depth = 0i64;
    let mut start = 0usize;
    for (i, &c) in b.iter().enumerate() {
        match c {
            b'(' | b'[' | b'{' | b'<' => depth += 1,
            b'>' if i > 0 && b[i - 1] == b'-' => {}
            b')' | b']' | b'}' | b'>' => depth -= 1,
            _ if c == sep && depth == 0 => {
                out.push((start, &s[start..i]));
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push((start, &s[start..]));
    out
}

/// Offset of the first *annotation* colon in `s` — a `:` at bracket
/// depth zero that is not part of a `::` path separator.
pub fn find_type_colon(s: &str) -> Option<usize> {
    let b = s.as_bytes();
    let mut depth = 0i64;
    let mut i = 0usize;
    while i < b.len() {
        match b[i] {
            b'(' | b'[' | b'<' => depth += 1,
            b'>' if i > 0 && b[i - 1] == b'-' => {}
            b')' | b']' | b'>' => depth -= 1,
            b':' => {
                if b.get(i + 1) == Some(&b':') {
                    i += 2;
                    continue;
                }
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
        i += 1;
    }
    None
}

/// The trailing identifier of `s`, if it ends in one.
pub fn trailing_ident(s: &str) -> Option<String> {
    let b = s.as_bytes();
    let mut start = b.len();
    while start > 0 && is_ident_byte(b[start - 1]) {
        start -= 1;
    }
    if start < b.len() {
        Some(s[start..].to_string())
    } else {
        None
    }
}

/// Word-boundary find (rejects `kw<` so `for<'a>` is not the `for` of
/// an `impl Trait for Type` header).
fn find_kw(s: &str, kw: &str) -> Option<usize> {
    let b = s.as_bytes();
    let mut from = 0;
    while let Some(rel) = s[from..].find(kw) {
        let i = from + rel;
        let before_ok = i == 0 || !is_ident_byte(b[i - 1]);
        let after = i + kw.len();
        let after_ok =
            after >= b.len() || (!is_ident_byte(b[after]) && b[after] != b'<');
        if before_ok && after_ok {
            return Some(i);
        }
        from = i + kw.len();
    }
    None
}

/// The method-owning type name of an `impl` header (text between
/// `impl` and `{`): `<T: Clone> Wrapper<T>` → `Wrapper`,
/// `Display for kern::LruQueue<K>` → `LruQueue`.
fn impl_type_name(header: &str) -> String {
    let mut s = header.trim();
    if s.starts_with('<') {
        let end = skip_angles(s.as_bytes(), 0);
        s = s[end.min(s.len())..].trim_start();
    }
    if let Some(i) = find_kw(s, "for") {
        s = s[i + 3..].trim_start();
    }
    if let Some(i) = s.find(" where") {
        s = &s[..i];
    }
    let s = s.trim_start_matches(['&', '*']).trim_start();
    let s = s.strip_prefix("mut ").unwrap_or(s).trim_start();
    let s = s.strip_prefix("dyn ").unwrap_or(s).trim_start();
    let base = match s.find('<') {
        Some(i) => &s[..i],
        None => s,
    };
    let base = base.trim_end();
    let seg = base.rsplit("::").next().unwrap_or(base);
    seg.chars().filter(|c| c.is_ascii_alphanumeric() || *c == '_').collect()
}

/// Module path from a repo-relative file path: `rust/src/par/pool.rs`
/// → `par::pool`, `rust/src/kern/simd/mod.rs` → `kern::simd`.
pub fn module_of(path: &str) -> String {
    let p = path.strip_prefix("rust/src/").unwrap_or(path);
    let p = p.strip_suffix(".rs").unwrap_or(p);
    let p = p.strip_suffix("/mod").unwrap_or(p);
    p.replace('/', "::")
}

/// Scope-stack entries, pushed at `{`.
enum Sc {
    /// A recognized fn body (index into `CrateModel::fns`).
    Fn(usize),
    /// An `impl TYPE` / `trait NAME` body.
    Qual(String),
    /// Any other brace (block, match, struct literal, module…).
    Other,
}

/// Parse named struct fields from the text between the braces.
fn parse_fields(body: &str) -> Vec<FieldDef> {
    let mut out = Vec::new();
    for (_, part) in split_top_level(body, b',') {
        let mut p = part.trim();
        // Field attributes are rare but legal; strip any `#[…]` runs.
        while let Some(r) = p.strip_prefix("#[") {
            match r.find(']') {
                Some(e) => p = r[e + 1..].trim_start(),
                None => break,
            }
        }
        let Some(ci) = find_type_colon(p) else { continue };
        let Some(name) = trailing_ident(p[..ci].trim_end()) else { continue };
        let ty = p[ci + 1..].trim().to_string();
        if !ty.is_empty() {
            out.push(FieldDef { name, ty });
        }
    }
    out
}

/// The item parser proper: one linear walk over `files[file].code`.
fn parse_file_items(model: &mut CrateModel, file: usize) {
    let code = model.files[file].code.clone();
    let in_test: Vec<bool> = model.files[file].scan.in_test.clone();
    let module = module_of(&model.files[file].path);
    let bytes = code.as_bytes();
    let n = bytes.len();

    let mut stack: Vec<Sc> = Vec::new();
    // A recognized item header whose `{` (at the recorded offset) is
    // still ahead of the cursor.
    let mut pending: Option<(usize, Sc)> = None;
    let mut line = 1usize;
    let mut i = 0usize;

    while i < n {
        let b = bytes[i];
        if b == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if b == b'{' {
            let sc = match &pending {
                Some((off, _)) if *off == i => {
                    let (_, sc) = pending.take().unwrap_or((0, Sc::Other));
                    sc
                }
                _ => Sc::Other,
            };
            if let Sc::Fn(idx) = sc {
                model.fns[idx].body = Some((i + 1)..(i + 1));
            }
            stack.push(sc);
            i += 1;
            continue;
        }
        if b == b'}' {
            if let Some(Sc::Fn(idx)) = stack.pop() {
                if let Some(r) = model.fns[idx].body.as_mut() {
                    r.end = i;
                }
            }
            i += 1;
            continue;
        }
        if !is_ident_byte(b) || (i > 0 && is_ident_byte(bytes[i - 1])) {
            i += 1;
            continue;
        }
        // Start of an identifier or keyword.
        let ws = i;
        let mut we = i;
        while we < n && is_ident_byte(bytes[we]) {
            we += 1;
        }
        if pending.is_some() {
            // Between a recognized header and its `{`: nothing in a
            // header starts a new item.
            i = we;
            continue;
        }
        match &code[ws..we] {
            "fn" => {
                let mut j = skip_ws_b(bytes, we);
                if j < n && bytes[j] == b'(' {
                    i = we; // `fn(…)` pointer type, not a definition
                    continue;
                }
                let ns = j;
                while j < n && is_ident_byte(bytes[j]) {
                    j += 1;
                }
                if j == ns {
                    i = we;
                    continue;
                }
                let name = code[ns..j].to_string();
                // Find the body `{` (or the `;` of a bodyless decl) at
                // paren/bracket depth zero.
                let mut k = j;
                let mut paren = 0i64;
                let mut bracket = 0i64;
                let mut open: Option<usize> = None;
                let mut semi: Option<usize> = None;
                while k < n {
                    match bytes[k] {
                        b'(' => paren += 1,
                        b')' => paren -= 1,
                        b'[' => bracket += 1,
                        b']' => bracket -= 1,
                        b'{' if paren == 0 && bracket == 0 => {
                            open = Some(k);
                            break;
                        }
                        b';' if paren == 0 && bracket == 0 => {
                            semi = Some(k);
                            break;
                        }
                        _ => {}
                    }
                    k += 1;
                }
                let sig_end = open.or(semi).unwrap_or(n);
                let mut qual = None;
                for s in stack.iter().rev() {
                    match s {
                        Sc::Qual(q) => {
                            qual = Some(q.clone());
                            break;
                        }
                        Sc::Fn(_) => break, // nested fn: not a method
                        Sc::Other => {}
                    }
                }
                let idx = model.fns.len();
                model.fns.push(FnDef {
                    name,
                    qual,
                    file,
                    line,
                    sig: code[ws..sig_end].trim().to_string(),
                    body: None,
                    is_test: in_test.get(line - 1).copied().unwrap_or(false),
                });
                model.files[file].fns.push(idx);
                match open {
                    Some(o) => {
                        pending = Some((o, Sc::Fn(idx)));
                        line += count_nl(&bytes[ws..o]);
                        i = o;
                    }
                    None => {
                        let end = semi.map(|s| s + 1).unwrap_or(n);
                        line += count_nl(&bytes[ws..end]);
                        i = end;
                    }
                }
            }
            "impl" | "trait" => {
                let is_trait = &code[ws..we] == "trait";
                let mut k = we;
                let mut paren = 0i64;
                let mut bracket = 0i64;
                let mut open: Option<usize> = None;
                while k < n {
                    match bytes[k] {
                        b'(' => paren += 1,
                        b')' => paren -= 1,
                        b'[' => bracket += 1,
                        b']' => bracket -= 1,
                        b'{' if paren == 0 && bracket == 0 => {
                            open = Some(k);
                            break;
                        }
                        b';' if paren == 0 && bracket == 0 => break,
                        _ => {}
                    }
                    k += 1;
                }
                let Some(o) = open else {
                    i = we;
                    continue;
                };
                let header = &code[we..o];
                let ty = if is_trait {
                    // First identifier after `trait`.
                    let hb = header.as_bytes();
                    let s = skip_ws_b(hb, 0);
                    let mut e = s;
                    while e < hb.len() && is_ident_byte(hb[e]) {
                        e += 1;
                    }
                    header[s..e].to_string()
                } else {
                    impl_type_name(header)
                };
                pending = Some((o, Sc::Qual(ty)));
                line += count_nl(&bytes[ws..o]);
                i = o;
            }
            "struct" | "enum" => {
                let is_enum = &code[ws..we] == "enum";
                let mut j = skip_ws_b(bytes, we);
                let ns = j;
                while j < n && is_ident_byte(bytes[j]) {
                    j += 1;
                }
                if j == ns {
                    i = we;
                    continue;
                }
                let name = code[ns..j].to_string();
                let item_line = line;
                let mut k = skip_ws_b(bytes, j);
                if k < n && bytes[k] == b'<' {
                    k = skip_angles(bytes, k);
                    k = skip_ws_b(bytes, k);
                }
                // A `where` clause may sit before the brace; scan to
                // the first `{`, `(`, or `;` at depth zero.
                let mut paren = 0i64;
                let mut bracket = 0i64;
                let mut body_open: Option<usize> = None;
                while k < n {
                    match bytes[k] {
                        b'(' if body_open.is_none() && paren == 0 && bracket == 0 && !is_enum => {
                            break; // tuple struct: no named fields
                        }
                        b'(' => paren += 1,
                        b')' => paren -= 1,
                        b'[' => bracket += 1,
                        b']' => bracket -= 1,
                        b'{' if paren == 0 && bracket == 0 => {
                            body_open = Some(k);
                            break;
                        }
                        b';' if paren == 0 && bracket == 0 => break,
                        _ => {}
                    }
                    k += 1;
                }
                if let Some(bo) = body_open {
                    if let Some(close) = match_delim_b(bytes, bo, b'{', b'}') {
                        let body = &code[bo + 1..close];
                        if is_enum {
                            let mut variants = Vec::new();
                            for (off, part) in split_top_level(body, b',') {
                                let pb = part.as_bytes();
                                let mut x = skip_ws_b(pb, 0);
                                // Strip variant attributes.
                                while pb.get(x) == Some(&b'#')
                                    && pb.get(x + 1) == Some(&b'[')
                                {
                                    match part[x..].find(']') {
                                        Some(e) => x = skip_ws_b(pb, x + e + 1),
                                        None => break,
                                    }
                                }
                                let vs = x;
                                while x < pb.len() && is_ident_byte(pb[x]) {
                                    x += 1;
                                }
                                if x > vs {
                                    let voff = bo + 1 + off + vs;
                                    variants.push((
                                        part[vs..x].to_string(),
                                        line_at(&code, voff),
                                    ));
                                }
                            }
                            model.enums.push(EnumDef { name, file, variants });
                        } else {
                            model.structs.push(StructDef {
                                name,
                                module: module.clone(),
                                file,
                                line: item_line,
                                fields: parse_fields(body),
                            });
                        }
                        line += count_nl(&bytes[ws..=close]);
                        i = close + 1;
                        continue;
                    }
                }
                if !is_enum {
                    // Tuple or unit struct: record it fieldless.
                    model.structs.push(StructDef {
                        name,
                        module: module.clone(),
                        file,
                        line: item_line,
                        fields: Vec::new(),
                    });
                }
                i = j;
            }
            "static" => {
                let mut j = skip_ws_b(bytes, we);
                // `static mut` (not in this tree, but cheap to accept).
                if code[j..].starts_with("mut") && !is_ident_byte(*bytes.get(j + 3).unwrap_or(&b'x'))
                {
                    j = skip_ws_b(bytes, j + 3);
                }
                let ns = j;
                while j < n && is_ident_byte(bytes[j]) {
                    j += 1;
                }
                if j == ns {
                    i = we;
                    continue;
                }
                let name = code[ns..j].to_string();
                let k = skip_ws_b(bytes, j);
                if bytes.get(k) != Some(&b':') {
                    i = we;
                    continue;
                }
                // Type runs to the `=` or `;` at bracket depth zero
                // (`=` inside generics is an associated-type binding).
                let ty_start = k + 1;
                let mut t = ty_start;
                let mut depth = 0i64;
                while t < n {
                    match bytes[t] {
                        b'(' | b'[' | b'<' => depth += 1,
                        b'>' if bytes[t - 1] == b'-' => {}
                        b')' | b']' | b'>' => depth -= 1,
                        b'=' | b';' if depth == 0 => break,
                        _ => {}
                    }
                    t += 1;
                }
                model.statics.push(StaticDef {
                    name,
                    ty: code[ty_start..t.min(n)].trim().to_string(),
                    file,
                    line,
                });
                line += count_nl(&bytes[ws..t.min(n)]);
                i = t.min(n);
            }
            _ => {
                i = we;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::scan;

    fn model_of(path: &str, src: &str) -> CrateModel {
        let mut m = CrateModel::default();
        m.add_file(path.to_string(), scan(src));
        m
    }

    #[test]
    fn free_fns_methods_and_bodies() {
        let src = "pub fn free(x: u32) -> u32 {\n    x + 1\n}\n\nstruct W { v: u32 }\n\nimpl W {\n    fn get(&self) -> u32 {\n        self.v\n    }\n}\n";
        let m = model_of("rust/src/serve/x.rs", src);
        assert_eq!(m.fns.len(), 2, "{:?}", m.fns);
        let free = &m.fns[0];
        assert_eq!(free.name, "free");
        assert_eq!(free.qual, None);
        assert_eq!(free.line, 1);
        let body = free.body.clone().expect("has body");
        assert!(m.files[0].code[body].contains("x + 1"));
        let get = &m.fns[1];
        assert_eq!(get.name, "get");
        assert_eq!(get.qual.as_deref(), Some("W"));
        assert!(m.files[0].code[get.body.clone().unwrap()].contains("self.v"));
        assert_eq!(m.structs.len(), 1);
        assert_eq!(m.structs[0].fields[0].name, "v");
    }

    #[test]
    fn impl_trait_for_type_quals_and_generics() {
        let src = "impl<T: Clone> Default for kern::Wrap<T> {\n    fn default() -> Self { Self }\n}\n";
        let m = model_of("rust/src/kern/w.rs", src);
        assert_eq!(m.fns[0].qual.as_deref(), Some("Wrap"));
    }

    #[test]
    fn nested_fn_is_not_a_method_and_sites_stay_separable() {
        let src = "impl W {\n    fn outer(&self) {\n        fn inner(y: u32) -> u32 { y }\n        let _ = inner(2);\n    }\n}\n";
        let m = model_of("rust/src/serve/x.rs", src);
        let outer = m.fns.iter().find(|f| f.name == "outer").unwrap();
        let inner = m.fns.iter().find(|f| f.name == "inner").unwrap();
        assert_eq!(outer.qual.as_deref(), Some("W"));
        assert_eq!(inner.qual, None, "nested fn must not inherit the impl qual");
        let ob = outer.body.clone().unwrap();
        let ib = inner.body.clone().unwrap();
        assert!(ob.start < ib.start && ib.end < ob.end, "nesting: {ob:?} {ib:?}");
    }

    #[test]
    fn lock_typed_fields_statics_and_params() {
        let src = "use std::sync::Mutex;\npub struct Shared {\n    pub state: Mutex<Vec<u32>>,\n    name: String,\n}\nstatic GATE: Mutex<()> = Mutex::new(());\nfn lock_recover<'a, T>(m: &'a Mutex<T>, n: &'a u64) -> std::sync::MutexGuard<'a, T> {\n    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)\n}\n";
        let m = model_of("rust/src/serve/mod.rs", src);
        let s = &m.structs[0];
        assert_eq!(s.module, "serve");
        assert!(CrateModel::is_lock_type(&s.fields[0].ty));
        assert!(!CrateModel::is_lock_type(&s.fields[1].ty));
        assert_eq!(m.statics[0].name, "GATE");
        assert!(CrateModel::is_lock_type(&m.statics[0].ty));
        let f = &m.fns[0];
        let params = f.params();
        assert_eq!(params.len(), 2, "{params:?}");
        assert_eq!(params[0].0, "m");
        assert!(params[0].1.contains("Mutex<T>"));
        assert!(f.returns_guard());
    }

    #[test]
    fn enum_variants_with_lines() {
        let src = "/// Kinds.\npub enum ErrorKind {\n    Other,\n    InvalidSpec,\n    RankDeficient,\n    Internal,\n}\n";
        let m = model_of("rust/src/error.rs", src);
        let e = &m.enums[0];
        assert_eq!(e.name, "ErrorKind");
        let got: Vec<(&str, usize)> =
            e.variants.iter().map(|(v, l)| (v.as_str(), *l)).collect();
        assert_eq!(
            got,
            vec![("Other", 3), ("InvalidSpec", 4), ("RankDeficient", 5), ("Internal", 6)]
        );
    }

    #[test]
    fn cfg_test_fns_are_marked_and_fn_pointer_types_skipped() {
        let src = "type Cb = fn(u32) -> u32;\nfn prod(cb: Cb) -> u32 { cb(1) }\n#[cfg(test)]\nmod tests {\n    fn helper() {}\n}\n";
        let m = model_of("rust/src/serve/x.rs", src);
        assert_eq!(m.fns.len(), 2, "{:?}", m.fns);
        assert!(!m.fns[0].is_test);
        assert_eq!(m.fns[1].name, "helper");
        assert!(m.fns[1].is_test);
    }

    #[test]
    fn impl_return_position_does_not_derail_scopes() {
        let src = "trait It {\n    fn go(&self) -> u32;\n}\nfn mk() -> impl Iterator<Item = u32> {\n    (0..3).map(|x| x)\n}\nstruct After { f: u32 }\n";
        let m = model_of("rust/src/serve/x.rs", src);
        let go = m.fns.iter().find(|f| f.name == "go").unwrap();
        assert_eq!(go.qual.as_deref(), Some("It"));
        assert!(go.body.is_none(), "bodyless trait decl");
        let mk = m.fns.iter().find(|f| f.name == "mk").unwrap();
        assert_eq!(mk.qual, None);
        assert!(mk.body.is_some());
        assert_eq!(m.structs[0].name, "After");
        assert_eq!(m.structs[0].fields[0].name, "f");
    }
}
