//! Integration tests for calars-audit: the bad fixture tree must fire
//! every rule at the exact expected `file:line`, the good tree must be
//! clean (with one reasoned suppression), and `--explain`/`--list`
//! must document every rule.
//!
//! The fixture trees under `tests/fixtures/` are miniature repo roots
//! (`tree_bad/rust/src/serve/…`) so the walker's path-scoping logic —
//! which rule applies where — is exercised end to end, not just the
//! matchers.

use calars_audit::rules::{rule_doc, Severity, RULES};
use calars_audit::{run_audit, run_cli, Config};
use std::path::PathBuf;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

#[test]
fn bad_tree_fires_every_rule_at_the_expected_lines() {
    let report = run_audit(&fixture("tree_bad"), &Config::default()).expect("walk");
    let got: Vec<(&str, usize, &str)> =
        report.findings.iter().map(|f| (f.path.as_str(), f.line, f.rule)).collect();
    let want: Vec<(&str, usize, &str)> = vec![
        ("rust/Cargo.toml", 5, "DEP-EXT"),
        ("rust/Cargo.toml", 6, "DEP-EXT"),
        ("rust/src/kern/evil.rs", 2, "UNSAFE-SCOPE"),
        ("rust/src/kern/simd/bad.rs", 1, "SIMD-TARGET"),
        ("rust/src/kern/simd/bad.rs", 1, "UNSAFE-BUDGET"),
        ("rust/src/kern/simd/bad.rs", 1, "UNSAFE-DOC"),
        ("rust/src/lars/core.rs", 6, "DET-TIME"),
        ("rust/src/lars/core.rs", 9, "DET-MAP"),
        ("rust/src/lars/core.rs", 12, "DET-SUM"),
        ("rust/src/lars/core.rs", 15, "DET-CMP"),
        ("rust/src/lars/markers.rs", 1, "ALLOW-REASON"),
        ("rust/src/lars/markers.rs", 3, "DET-SUM"),
        ("rust/src/lars/markers.rs", 5, "ALLOW-REASON"),
        ("rust/src/lars/markers.rs", 6, "ALLOW-UNUSED"),
        ("rust/src/par/raw.rs", 2, "UNSAFE-BUDGET"),
        ("rust/src/par/raw.rs", 2, "UNSAFE-DOC"),
        ("rust/src/serve/handlers.rs", 5, "PANIC-UNWRAP"),
        ("rust/src/serve/handlers.rs", 6, "PANIC-UNWRAP"),
        ("rust/src/serve/handlers.rs", 7, "PANIC-LOCK"),
        ("rust/src/serve/handlers.rs", 9, "PANIC-UNWRAP"),
    ];
    assert_eq!(got, want, "full findings: {:#?}", report.findings);
    assert_eq!(report.errors(), 19);
    assert_eq!(report.warnings(), 1);
    assert_eq!(report.suppressed, 0, "a reasonless marker must not suppress");
    assert!(!report.is_clean(false));
    // Severity split: exactly ALLOW-UNUSED is the warning.
    for f in &report.findings {
        let expect = if f.rule == "ALLOW-UNUSED" { Severity::Warning } else { Severity::Error };
        assert_eq!(f.severity, expect, "{}:{} {}", f.path, f.line, f.rule);
    }
}

#[test]
fn bad_tree_diagnostics_render_as_file_line() {
    let report = run_audit(&fixture("tree_bad"), &Config::default()).expect("walk");
    let rendered = report.render();
    assert!(
        rendered.contains("rust/src/serve/handlers.rs:5: error[PANIC-UNWRAP]"),
        "{rendered}"
    );
    assert!(rendered.contains("rust/Cargo.toml:5: error[DEP-EXT]"), "{rendered}");
    assert!(rendered.contains("19 error(s), 1 warning(s)"), "{rendered}");
}

#[test]
fn good_tree_is_clean_with_one_reasoned_suppression() {
    let report = run_audit(&fixture("tree_good"), &Config::default()).expect("walk");
    assert!(report.findings.is_empty(), "{:#?}", report.findings);
    assert_eq!(report.suppressed, 1, "the reasoned DET-SUM allow must count");
    assert_eq!(report.files_scanned, 4);
    assert_eq!(report.manifests_checked, 2);
    assert!(report.is_clean(true), "clean even under --deny-warnings");
}

#[test]
fn warnings_gate_only_under_deny_warnings() {
    // A tree whose only problem is an unused-but-reasoned marker:
    // build the policy check from the bad tree's report shape instead
    // of a third fixture — is_clean is a pure function of the counts.
    let report = run_audit(&fixture("tree_bad"), &Config::default()).expect("walk");
    assert!(!report.is_clean(false), "errors always gate");
    let warnings_only = calars_audit::Report {
        findings: report
            .findings
            .into_iter()
            .filter(|f| f.severity == Severity::Warning)
            .collect(),
        ..Default::default()
    };
    assert!(warnings_only.is_clean(false));
    assert!(!warnings_only.is_clean(true));
}

#[test]
fn every_rule_is_documented_for_explain_and_list() {
    assert_eq!(RULES.len(), 16);
    for r in RULES {
        assert!(!r.summary.is_empty(), "{} needs a summary", r.id);
        assert!(r.explain.len() > 80, "{} needs a real explanation", r.id);
        assert!(rule_doc(r.id).is_some());
    }
    // The determinism rules must point at the contract vocabulary.
    assert!(rule_doc("DET-CMP").unwrap().explain.contains("total_cmp"));
    assert!(rule_doc("DET-SUM").unwrap().explain.contains("canonical"));
    assert!(rule_doc("PANIC-LOCK").unwrap().explain.contains("into_inner"));
    assert!(rule_doc("SIMD-TARGET").unwrap().explain.contains("target_feature"));
    assert!(rule_doc("UNSAFE-SCOPE").unwrap().explain.contains("kern/simd"));
    // The interprocedural rules must document their escape hatches.
    assert!(rule_doc("PANIC-REACH").unwrap().explain.contains("catch_unwind"));
    assert!(rule_doc("LOCK-ORDER").unwrap().explain.contains("both acquisition sites"));
    assert!(rule_doc("ERR-MAP").unwrap().explain.contains("docs/API.md"));
    assert!(rule_doc("UNSAFE-BUDGET").unwrap().explain.contains("--update-unsafe-ledger"));
    assert!(rule_doc("NOPE").is_none());
}

#[test]
fn panic_reach_fixture_fires_on_the_reachable_unwrap_and_index_only() {
    // Firing: the unwrap two hops below handle_fit, and the untrusted
    // index in handle_first.  Non-firing: orphan (unreachable) and
    // risky (only reachable through a catch_unwind shield).
    let report = run_audit(&fixture("tree_panic_reach"), &Config::default()).expect("walk");
    let got: Vec<(&str, usize, &str)> =
        report.findings.iter().map(|f| (f.path.as_str(), f.line, f.rule)).collect();
    assert_eq!(
        got,
        vec![
            ("rust/src/lars/fit.rs", 4, "PANIC-REACH"),
            ("rust/src/serve/http.rs", 16, "PANIC-REACH"),
        ],
        "full findings: {:#?}",
        report.findings
    );
    assert!(
        report.findings[0].message.contains("handle_fit -> solve"),
        "the diagnostic must carry the call chain: {}",
        report.findings[0].message
    );
    assert!(report.findings[1].message.contains("unchecked index"));
}

#[test]
fn lock_order_fixture_reports_the_cycle_with_both_sites() {
    // Firing: State taken a→b in ab() and b→a in ba().  Non-firing:
    // Pair, consistently x→y in both methods.
    let report = run_audit(&fixture("tree_lock_order"), &Config::default()).expect("walk");
    let got: Vec<(&str, usize, &str)> =
        report.findings.iter().map(|f| (f.path.as_str(), f.line, f.rule)).collect();
    assert_eq!(
        got,
        vec![("rust/src/serve/state.rs", 13, "LOCK-ORDER")],
        "full findings: {:#?}",
        report.findings
    );
    let m = &report.findings[0].message;
    assert!(
        m.contains("State.a (rust/src/serve/state.rs:12) then State.b (rust/src/serve/state.rs:13)"),
        "{m}"
    );
    assert!(
        m.contains("State.b (rust/src/serve/state.rs:18) then State.a (rust/src/serve/state.rs:19)"),
        "{m}"
    );
    assert!(!m.contains("Pair"), "consistent order must stay out of the cycle: {m}");
}

#[test]
fn err_map_fixture_flags_each_drift_kind_once() {
    // Firing: the unmapped variant, the ghost metric, the ghost route.
    // Non-firing: Mapped, /fit and calars_fit_total, all documented.
    let report = run_audit(&fixture("tree_err_map"), &Config::default()).expect("walk");
    let got: Vec<(&str, usize, &str)> =
        report.findings.iter().map(|f| (f.path.as_str(), f.line, f.rule)).collect();
    assert_eq!(
        got,
        vec![
            ("rust/src/error.rs", 5, "ERR-MAP"),
            ("rust/src/obs/metrics.rs", 4, "ERR-MAP"),
            ("rust/src/serve/http.rs", 11, "ERR-MAP"),
        ],
        "full findings: {:#?}",
        report.findings
    );
    assert!(report.findings[0].message.contains("Orphaned"));
    assert!(report.findings[1].message.contains("calars_ghost_total"));
    assert!(report.findings[2].message.contains("/undocumented"));
}

#[test]
fn unsafe_budget_fixture_gates_growth_and_warns_on_stale_entries() {
    // Firing: raw.rs grew past its ledgered count (error at the first
    // over-budget site) and gone.rs is stale (warning at the ledger
    // line).  Non-firing: w.rs, whose count matches.
    let report = run_audit(&fixture("tree_unsafe_budget"), &Config::default()).expect("walk");
    let got: Vec<(&str, usize, &str, Severity)> = report
        .findings
        .iter()
        .map(|f| (f.path.as_str(), f.line, f.rule, f.severity))
        .collect();
    assert_eq!(
        got,
        vec![
            ("rust/src/par/raw.rs", 10, "UNSAFE-BUDGET", Severity::Error),
            ("tools/audit/unsafe.ledger", 4, "UNSAFE-BUDGET", Severity::Warning),
        ],
        "full findings: {:#?}",
        report.findings
    );
    assert!(!report.is_clean(false), "budget growth must gate");
}

#[test]
fn json_and_github_renderings_carry_every_finding() {
    let report = run_audit(&fixture("tree_bad"), &Config::default()).expect("walk");
    let json = report.render_json();
    assert!(json.contains("\"rule\":\"PANIC-UNWRAP\""), "{json}");
    assert!(json.contains("\"severity\":\"warning\""), "{json}");
    assert!(json.contains("\"errors\":19"), "{json}");
    assert!(json.starts_with('{') && json.trim_end().ends_with('}'), "{json}");
    let gh = report.render_github();
    assert!(
        gh.contains("::error file=rust/src/serve/handlers.rs,line=5,title=PANIC-UNWRAP::"),
        "{gh}"
    );
    assert!(
        gh.contains("::warning file=rust/src/lars/markers.rs,line=6,title=ALLOW-UNUSED::"),
        "{gh}"
    );
    assert_eq!(gh.lines().count(), report.findings.len());
}

#[test]
fn cli_exit_codes() {
    let bad = fixture("tree_bad").to_string_lossy().to_string();
    let good = fixture("tree_good").to_string_lossy().to_string();
    assert_eq!(run_cli(&["--root".to_string(), good.clone()]), 0);
    assert_eq!(run_cli(&["--root".to_string(), bad.clone()]), 1);
    assert_eq!(run_cli(&["--root".to_string(), good, "--deny-warnings".to_string()]), 0);
    assert_eq!(run_cli(&["--root".to_string(), bad.clone(), "--json".to_string()]), 1);
    assert_eq!(run_cli(&["--root".to_string(), bad.clone(), "--github".to_string()]), 1);
    assert_eq!(
        run_cli(&[
            "--root".to_string(),
            bad.clone(),
            "--json".to_string(),
            "--github".to_string()
        ]),
        2,
        "--json and --github are mutually exclusive"
    );
    assert_eq!(run_cli(&["--explain".to_string(), "DET-CMP".to_string()]), 0);
    assert_eq!(run_cli(&["--explain".to_string(), "BOGUS".to_string()]), 2);
    assert_eq!(run_cli(&["--list".to_string()]), 0);
    assert_eq!(run_cli(&["--frobnicate".to_string()]), 2);
}

#[test]
fn the_real_tree_is_clean_under_deny_warnings() {
    // The acceptance criterion in one test: the audit over the actual
    // repository must pass with zero unsuppressed findings — every
    // exception in the tree is a reasoned allow marker.
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .and_then(|p| p.parent())
        .expect("workspace root")
        .to_path_buf();
    let report = run_audit(&root, &Config::default()).expect("walk");
    assert!(
        report.findings.is_empty(),
        "the tree must audit clean; findings:\n{}",
        report.render()
    );
    assert!(report.is_clean(true));
    assert!(report.files_scanned > 50, "walked {} files", report.files_scanned);
}
