//! Integration tests for calars-audit: the bad fixture tree must fire
//! every rule at the exact expected `file:line`, the good tree must be
//! clean (with one reasoned suppression), and `--explain`/`--list`
//! must document every rule.
//!
//! The fixture trees under `tests/fixtures/` are miniature repo roots
//! (`tree_bad/rust/src/serve/…`) so the walker's path-scoping logic —
//! which rule applies where — is exercised end to end, not just the
//! matchers.

use calars_audit::rules::{rule_doc, Severity, RULES};
use calars_audit::{run_audit, run_cli, Config};
use std::path::PathBuf;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

#[test]
fn bad_tree_fires_every_rule_at_the_expected_lines() {
    let report = run_audit(&fixture("tree_bad"), &Config::default()).expect("walk");
    let got: Vec<(&str, usize, &str)> =
        report.findings.iter().map(|f| (f.path.as_str(), f.line, f.rule)).collect();
    let want: Vec<(&str, usize, &str)> = vec![
        ("rust/Cargo.toml", 5, "DEP-EXT"),
        ("rust/Cargo.toml", 6, "DEP-EXT"),
        ("rust/src/kern/evil.rs", 2, "UNSAFE-SCOPE"),
        ("rust/src/kern/simd/bad.rs", 1, "SIMD-TARGET"),
        ("rust/src/kern/simd/bad.rs", 1, "UNSAFE-DOC"),
        ("rust/src/lars/core.rs", 6, "DET-TIME"),
        ("rust/src/lars/core.rs", 9, "DET-MAP"),
        ("rust/src/lars/core.rs", 12, "DET-SUM"),
        ("rust/src/lars/core.rs", 15, "DET-CMP"),
        ("rust/src/lars/markers.rs", 1, "ALLOW-REASON"),
        ("rust/src/lars/markers.rs", 3, "DET-SUM"),
        ("rust/src/lars/markers.rs", 5, "ALLOW-REASON"),
        ("rust/src/lars/markers.rs", 6, "ALLOW-UNUSED"),
        ("rust/src/par/raw.rs", 2, "UNSAFE-DOC"),
        ("rust/src/serve/handlers.rs", 5, "PANIC-UNWRAP"),
        ("rust/src/serve/handlers.rs", 6, "PANIC-UNWRAP"),
        ("rust/src/serve/handlers.rs", 7, "PANIC-LOCK"),
        ("rust/src/serve/handlers.rs", 9, "PANIC-UNWRAP"),
    ];
    assert_eq!(got, want, "full findings: {:#?}", report.findings);
    assert_eq!(report.errors(), 17);
    assert_eq!(report.warnings(), 1);
    assert_eq!(report.suppressed, 0, "a reasonless marker must not suppress");
    assert!(!report.is_clean(false));
    // Severity split: exactly ALLOW-UNUSED is the warning.
    for f in &report.findings {
        let expect = if f.rule == "ALLOW-UNUSED" { Severity::Warning } else { Severity::Error };
        assert_eq!(f.severity, expect, "{}:{} {}", f.path, f.line, f.rule);
    }
}

#[test]
fn bad_tree_diagnostics_render_as_file_line() {
    let report = run_audit(&fixture("tree_bad"), &Config::default()).expect("walk");
    let rendered = report.render();
    assert!(
        rendered.contains("rust/src/serve/handlers.rs:5: error[PANIC-UNWRAP]"),
        "{rendered}"
    );
    assert!(rendered.contains("rust/Cargo.toml:5: error[DEP-EXT]"), "{rendered}");
    assert!(rendered.contains("17 error(s), 1 warning(s)"), "{rendered}");
}

#[test]
fn good_tree_is_clean_with_one_reasoned_suppression() {
    let report = run_audit(&fixture("tree_good"), &Config::default()).expect("walk");
    assert!(report.findings.is_empty(), "{:#?}", report.findings);
    assert_eq!(report.suppressed, 1, "the reasoned DET-SUM allow must count");
    assert_eq!(report.files_scanned, 4);
    assert_eq!(report.manifests_checked, 2);
    assert!(report.is_clean(true), "clean even under --deny-warnings");
}

#[test]
fn warnings_gate_only_under_deny_warnings() {
    // A tree whose only problem is an unused-but-reasoned marker:
    // build the policy check from the bad tree's report shape instead
    // of a third fixture — is_clean is a pure function of the counts.
    let report = run_audit(&fixture("tree_bad"), &Config::default()).expect("walk");
    assert!(!report.is_clean(false), "errors always gate");
    let warnings_only = calars_audit::Report {
        findings: report
            .findings
            .into_iter()
            .filter(|f| f.severity == Severity::Warning)
            .collect(),
        ..Default::default()
    };
    assert!(warnings_only.is_clean(false));
    assert!(!warnings_only.is_clean(true));
}

#[test]
fn every_rule_is_documented_for_explain_and_list() {
    assert_eq!(RULES.len(), 12);
    for r in RULES {
        assert!(!r.summary.is_empty(), "{} needs a summary", r.id);
        assert!(r.explain.len() > 80, "{} needs a real explanation", r.id);
        assert!(rule_doc(r.id).is_some());
    }
    // The determinism rules must point at the contract vocabulary.
    assert!(rule_doc("DET-CMP").unwrap().explain.contains("total_cmp"));
    assert!(rule_doc("DET-SUM").unwrap().explain.contains("canonical"));
    assert!(rule_doc("PANIC-LOCK").unwrap().explain.contains("into_inner"));
    assert!(rule_doc("SIMD-TARGET").unwrap().explain.contains("target_feature"));
    assert!(rule_doc("UNSAFE-SCOPE").unwrap().explain.contains("kern/simd"));
    assert!(rule_doc("NOPE").is_none());
}

#[test]
fn cli_exit_codes() {
    let bad = fixture("tree_bad").to_string_lossy().to_string();
    let good = fixture("tree_good").to_string_lossy().to_string();
    assert_eq!(run_cli(&["--root".to_string(), good.clone()]), 0);
    assert_eq!(run_cli(&["--root".to_string(), bad.clone()]), 1);
    assert_eq!(run_cli(&["--root".to_string(), good, "--deny-warnings".to_string()]), 0);
    assert_eq!(run_cli(&["--explain".to_string(), "DET-CMP".to_string()]), 0);
    assert_eq!(run_cli(&["--explain".to_string(), "BOGUS".to_string()]), 2);
    assert_eq!(run_cli(&["--list".to_string()]), 0);
    assert_eq!(run_cli(&["--frobnicate".to_string()]), 2);
}

#[test]
fn the_real_tree_is_clean_under_deny_warnings() {
    // The acceptance criterion in one test: the audit over the actual
    // repository must pass with zero unsuppressed findings — every
    // exception in the tree is a reasoned allow marker.
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .and_then(|p| p.parent())
        .expect("workspace root")
        .to_path_buf();
    let report = run_audit(&root, &Config::default()).expect("walk");
    assert!(
        report.findings.is_empty(),
        "the tree must audit clean; findings:\n{}",
        report.render()
    );
    assert!(report.is_clean(true));
    assert!(report.files_scanned > 50, "walked {} files", report.files_scanned);
}
