//! Lock-order fixture: `State` is acquired in both orders (a genuine
//! two-mutex deadlock), `Pair` is consistently ordered and clean.
use std::sync::{Mutex, PoisonError};

pub struct State {
    a: Mutex<u32>,
    b: Mutex<u32>,
}

impl State {
    pub fn ab(&self) -> u32 {
        let ga = self.a.lock().unwrap_or_else(PoisonError::into_inner);
        let gb = self.b.lock().unwrap_or_else(PoisonError::into_inner);
        *ga + *gb
    }

    pub fn ba(&self) -> u32 {
        let gb = self.b.lock().unwrap_or_else(PoisonError::into_inner);
        let ga = self.a.lock().unwrap_or_else(PoisonError::into_inner);
        *ga + *gb
    }
}

pub struct Pair {
    x: Mutex<u32>,
    y: Mutex<u32>,
}

impl Pair {
    pub fn xy(&self) -> u32 {
        let gx = self.x.lock().unwrap_or_else(PoisonError::into_inner);
        let gy = self.y.lock().unwrap_or_else(PoisonError::into_inner);
        *gx + *gy
    }

    pub fn xy_again(&self) -> u32 {
        let gx = self.x.lock().unwrap_or_else(PoisonError::into_inner);
        let gy = self.y.lock().unwrap_or_else(PoisonError::into_inner);
        *gx * *gy
    }
}
