pub fn read(p: *const f64) -> f64 {
    // SAFETY: fixture — the caller guarantees p is valid and live.
    unsafe { *p }
}

pub fn read_inline(p: *const f64) -> f64 {
    unsafe { *p } // SAFETY: same-line comments are accepted too.
}
