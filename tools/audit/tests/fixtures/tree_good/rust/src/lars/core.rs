//! Good lars fixture: total comparators and a reasoned allow.

pub fn pick(c: &[f64]) -> usize {
    (0..c.len())
        .max_by(|&i, &j| c[i].total_cmp(&c[j]))
        .unwrap_or(0)
}

pub fn residual(v: &[f64]) -> f64 {
    // audit: allow(DET-SUM) -- serial fixed-order sum, fixture for marker suppression
    v.iter().sum::<f64>()
}
