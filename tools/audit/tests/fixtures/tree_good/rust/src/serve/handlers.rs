//! Good serve fixture: typed errors and poison recovery — in prose,
//! even `.unwrap()` and `panic!` in a comment must not fire.

pub fn respond(x: Option<u32>, m: &std::sync::Mutex<u32>) -> Result<u32, String> {
    let v = x.ok_or_else(|| "missing (not .unwrap())".to_string())?;
    let g = m.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    Ok(v + *g)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        let x: Option<u32> = Some(3);
        assert_eq!(x.unwrap(), 3);
    }
}
