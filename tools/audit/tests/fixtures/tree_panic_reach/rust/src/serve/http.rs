//! Panic-reach fixture: handlers reach into the fit layer.

pub fn handle_fit(req: &str) -> String {
    crate::lars::fit::solve(req)
}

pub fn handle_shielded(req: &str) -> String {
    let r = std::panic::catch_unwind(|| crate::lars::fit::risky(req.len()));
    match r {
        Ok(s) => s,
        Err(_) => String::from("recovered"),
    }
}

pub fn handle_first(body: &str) -> u8 {
    body.as_bytes()[0]
}
