//! The fit layer: one reachable unwrap, one orphan, one shielded panic.

pub fn solve(req: &str) -> String {
    let k: usize = req.parse().unwrap();
    "k".repeat(k)
}

pub fn risky(n: usize) -> String {
    panic!("boom {n}")
}

pub fn orphan(n: usize) -> usize {
    n.checked_add(1).unwrap()
}
