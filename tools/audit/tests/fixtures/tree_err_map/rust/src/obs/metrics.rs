//! One documented metric, one ghost.

pub fn names() -> [&'static str; 2] {
    ["calars_fit_total", "calars_ghost_total"]
}
