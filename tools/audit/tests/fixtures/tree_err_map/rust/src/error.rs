//! Error fixture: `Orphaned` has no HTTP status mapping.

pub enum ErrorKind {
    Mapped,
    Orphaned,
}
