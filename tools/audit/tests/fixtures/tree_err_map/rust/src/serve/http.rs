//! Maps `Mapped` only; serves one documented and one ghost route.

pub fn error_status(k: &crate::error::ErrorKind) -> u16 {
    match k {
        crate::error::ErrorKind::Mapped => 400,
        _ => 500,
    }
}

pub fn routes() -> [&'static str; 2] {
    ["/fit", "/undocumented"]
}
