//! Bad serve fixture: every panic-safety rule fires. Never compiled —
//! the audit integration tests only scan this tree.

pub fn respond(x: Option<u32>, m: &std::sync::Mutex<u32>) -> u32 {
    let v = x.unwrap();
    let w = x.expect("present");
    let g = m.lock().unwrap();
    if v == 0 {
        panic!("zero");
    }
    v + w + *g
}
