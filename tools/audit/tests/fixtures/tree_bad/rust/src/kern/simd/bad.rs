pub unsafe fn load(p: *const f64) -> f64 {
    *p
}
