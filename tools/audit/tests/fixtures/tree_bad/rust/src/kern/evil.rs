pub fn read(p: *const f64) -> f64 {
    unsafe { *p }
}
