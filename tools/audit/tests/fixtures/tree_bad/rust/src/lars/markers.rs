// audit: allow(DET-SUM)
pub fn s(v: &[f64]) -> f64 {
    v.iter().sum::<f64>()
}
// audit: allow(NOT-A-RULE) -- typo'd rule id
// audit: allow(DET-CMP) -- nothing on the next line to suppress
pub fn t() {}
