//! Bad lars fixture: every determinism rule fires.
use std::collections::HashMap;
use std::time::Instant;

pub fn pick(c: &[f64]) -> usize {
    let t0 = Instant::now();
    let mut groups: HashMap<u64, usize> = HashMap::new();
    groups.insert(0, 1);
    for (k, v) in groups.iter() {
        let _ = (k, v);
    }
    let s: f64 = c.iter().sum::<f64>();
    let _ = (t0, s);
    (0..c.len())
        .max_by(|&i, &j| c[i].partial_cmp(&c[j]).unwrap())
        .unwrap_or(0)
}
