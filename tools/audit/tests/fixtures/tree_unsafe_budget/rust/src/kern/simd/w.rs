/// Lane-wise load.
///
/// SAFETY: callers hold a dispatch token only constructed after
/// `is_x86_feature_detected!("avx2")` passed.
#[target_feature(enable = "avx2")]
pub unsafe fn load(p: *const f64) -> f64 {
    *p
}
