//! Two unsafe blocks; the ledger grants one.

pub fn read(p: *const f64) -> f64 {
    // SAFETY: fixture — the caller guarantees p is valid and live.
    unsafe { *p }
}

pub fn read2(p: *const f64) -> f64 {
    // SAFETY: fixture — the caller guarantees p is valid and live.
    unsafe { *p }
}
