#!/usr/bin/env python3
"""Faithful Python mirror of the calars-audit v2 interprocedural rules.

Dev-only verification harness: replicates lexer.rs + parse.rs +
callgraph.rs + locks.rs + contract.rs byte-for-byte in behavior so the
four new rule families (PANIC-REACH, LOCK-ORDER, ERR-MAP,
UNSAFE-BUDGET) can be exercised against the fixture trees and the real
tree without a Rust toolchain in the container.  Not shipped into any
build; tracked so the next session can replay the prediction.

Usage: python3 mirror.py <root> [--update-ledger]
"""

import os
import sys

sys.setrecursionlimit(100000)


def is_id(c):
    return ("a" <= c <= "z") or ("A" <= c <= "Z") or ("0" <= c <= "9") or c == "_"


def is_id_b(b):
    c = chr(b) if b < 128 else " "
    return is_id(c)


# ── lexer.rs ─────────────────────────────────────────────────────────


def raw_str_at(bs, i):
    j = i
    if j < len(bs) and bs[j] == ord("b"):
        j += 1
    if j >= len(bs) or bs[j] != ord("r"):
        return None
    j += 1
    hashes = 0
    while j < len(bs) and bs[j] == ord("#"):
        hashes += 1
        j += 1
    if j < len(bs) and bs[j] == ord('"'):
        return (hashes, j + 1 - i)
    return None


def scan_quote(bs, i, code):
    n = len(bs)
    if i + 1 < n and bs[i + 1] == ord("\\"):
        code.append("'")
        code.append(" ")
        j = i + 2
        if j < n and bs[j] != ord("\n"):
            code.append(" ")
            j += 1
        while j < n and bs[j] != ord("'") and bs[j] != ord("\n"):
            code.append(" ")
            j += 1
        if j < n and bs[j] == ord("'"):
            code.append("'")
            return j + 1
        return j
    if i + 1 < n and bs[i + 1] != ord("'"):
        for j in range(i + 2, min(i + 6, n)):
            if bs[j] == ord("'"):
                if (
                    j == i + 2
                    and is_id_b(bs[i + 1])
                    and j + 1 < n
                    and is_id_b(bs[j + 1])
                ):
                    break
                code.append("'")
                for _ in range(i + 1, j):
                    code.append(" ")
                code.append("'")
                return j + 1
            if bs[j] >= 128:
                continue
            if j == i + 2 and not is_id_b(bs[j]):
                break
    code.append("'")
    return i + 1


def mark_test_regions(lines):
    in_test = [False] * len(lines)
    i = 0
    while i < len(lines):
        squashed = "".join(c for c in lines[i][0] if not c.isspace())
        if "#[cfg(test)]" not in squashed:
            i += 1
            continue
        start = i
        depth = 0
        opened = False
        j = i
        while j < len(lines):
            for c in lines[j][0]:
                if c == "{":
                    depth += 1
                    opened = True
                elif c == "}":
                    depth -= 1
            if opened and depth <= 0:
                break
            j += 1
        end = min(j, len(lines) - 1)
        for t in range(start, end + 1):
            in_test[t] = True
        i = end + 1
    return in_test


def scan(src_bytes):
    bs = src_bytes
    n = len(bs)
    lines = []
    cur_code = []
    cur_comment = []
    strs = []
    lit_line = 0
    lit_text = []
    mode = ("normal",)
    i = 0
    prev_code = 0
    while i < n:
        b = bs[i]
        if b == ord("\n"):
            lines.append(("".join(cur_code), "".join(cur_comment)))
            cur_code = []
            cur_comment = []
            if mode[0] == "line":
                mode = ("normal",)
            if mode[0] in ("str", "rawstr"):
                lit_text.append("\n")
            i += 1
            continue
        m = mode[0]
        if m == "line":
            cur_comment.append(chr(b) if b < 128 else " ")
            i += 1
        elif m == "block":
            depth = mode[1]
            if b == ord("/") and i + 1 < n and bs[i + 1] == ord("*"):
                mode = ("block", depth + 1)
                i += 2
            elif b == ord("*") and i + 1 < n and bs[i + 1] == ord("/"):
                mode = ("normal",) if depth == 1 else ("block", depth - 1)
                i += 2
            else:
                cur_comment.append(chr(b) if b < 128 else " ")
                i += 1
        elif m == "str":
            if b == ord("\\"):
                cur_code.append(" ")
                lit_text.append("\\")
                if i + 1 < n and bs[i + 1] != ord("\n"):
                    cur_code.append(" ")
                    lit_text.append(chr(bs[i + 1]) if bs[i + 1] < 128 else " ")
                    i += 2
                else:
                    i += 1
            elif b == ord('"'):
                cur_code.append('"')
                prev_code = ord('"')
                mode = ("normal",)
                strs.append((lit_line, "".join(lit_text)))
                lit_text = []
                i += 1
            else:
                cur_code.append(" ")
                lit_text.append(chr(b) if b < 128 else " ")
                i += 1
        elif m == "rawstr":
            hashes = mode[1]
            if (
                b == ord('"')
                and n - (i + 1) >= hashes
                and all(bs[i + 1 + k] == ord("#") for k in range(hashes))
            ):
                for _ in range(hashes + 1):
                    cur_code.append(" ")
                prev_code = ord('"')
                mode = ("normal",)
                strs.append((lit_line, "".join(lit_text)))
                lit_text = []
                i += 1 + hashes
            else:
                cur_code.append(" ")
                lit_text.append(chr(b) if b < 128 else " ")
                i += 1
        else:
            if b == ord("/") and i + 1 < n and bs[i + 1] == ord("/"):
                mode = ("line",)
                i += 2
                if i < n and bs[i] in (ord("/"), ord("!")):
                    i += 1
            elif b == ord("/") and i + 1 < n and bs[i + 1] == ord("*"):
                mode = ("block", 1)
                i += 2
            elif b == ord('"'):
                cur_code.append('"')
                mode = ("str",)
                lit_line = len(lines) + 1
                lit_text = []
                i += 1
            elif (
                b in (ord("r"), ord("b"))
                and not is_id_b(prev_code)
                and raw_str_at(bs, i) is not None
            ):
                hashes, consumed = raw_str_at(bs, i)
                for _ in range(consumed):
                    cur_code.append(" ")
                mode = ("rawstr", hashes)
                lit_line = len(lines) + 1
                lit_text = []
                i += consumed
            elif (
                b == ord("b")
                and i + 1 < n
                and bs[i + 1] == ord('"')
                and not is_id_b(prev_code)
            ):
                cur_code.append("b")
                prev_code = ord("b")
                i += 1
            elif b == ord("'"):
                i = scan_quote(bs, i, cur_code)
                prev_code = ord("'")
            else:
                cur_code.append(chr(b) if b < 128 else " ")
                prev_code = b if b < 128 else ord(" ")
                i += 1
    lines.append(("".join(cur_code), "".join(cur_comment)))
    in_test = mark_test_regions(lines)
    return lines, in_test, strs


# ── rules.rs helpers ─────────────────────────────────────────────────


def word_occurrences(text, needle):
    out = []
    start = 0
    while True:
        i = text.find(needle, start)
        if i < 0:
            return out
        before_ok = i == 0 or not is_id(text[i - 1])
        after = i + len(needle)
        after_ok = after >= len(text) or not is_id(text[after])
        if before_ok and after_ok:
            out.append(i)
        start = i + len(needle)


def match_paren(text, open_i):
    depth = 0
    for k in range(open_i, len(text)):
        c = text[k]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return k + 1
    return None


def skip_ws(text, i):
    while i < len(text) and text[i] in " \t\n\r\x0b\x0c":
        i += 1
    return i


def line_at(code, off):
    return code.count("\n", 0, min(off, len(code))) + 1


# ── parse.rs ─────────────────────────────────────────────────────────


def match_delim(text, open_i, ob, cb):
    depth = 0
    i = open_i
    while i < len(text):
        c = text[i]
        if c == ob:
            depth += 1
        elif c == cb:
            depth -= 1
            if depth < 0:
                return None
            if depth == 0:
                return i
        i += 1
    return None


def skip_angles(text, i):
    depth = 0
    while i < len(text):
        c = text[i]
        if c == "<":
            depth += 1
        elif c == ">" and i > 0 and text[i - 1] == "-":
            pass
        elif c == ">":
            depth -= 1
            if depth <= 0:
                return i + 1
        i += 1
    return i


def split_top_level(s, sep):
    out = []
    depth = 0
    start = 0
    for i, c in enumerate(s):
        if c in "([{<":
            depth += 1
        elif c == ">" and i > 0 and s[i - 1] == "-":
            pass
        elif c in ")]}>":
            depth -= 1
        elif c == sep and depth == 0:
            out.append((start, s[start:i]))
            start = i + 1
    out.append((start, s[start:]))
    return out


def find_type_colon(s):
    depth = 0
    i = 0
    while i < len(s):
        c = s[i]
        if c in "([<":
            depth += 1
        elif c == ">" and i > 0 and s[i - 1] == "-":
            pass
        elif c in ")]>":
            depth -= 1
        elif c == ":":
            if i + 1 < len(s) and s[i + 1] == ":":
                i += 2
                continue
            if depth == 0:
                return i
        i += 1
    return None


def trailing_ident(s):
    start = len(s)
    while start > 0 and is_id(s[start - 1]):
        start -= 1
    return s[start:] if start < len(s) else None


def find_kw(s, kw):
    frm = 0
    while True:
        rel = s.find(kw, frm)
        if rel < 0:
            return None
        i = rel
        before_ok = i == 0 or not is_id(s[i - 1])
        after = i + len(kw)
        after_ok = after >= len(s) or (not is_id(s[after]) and s[after] != "<")
        if before_ok and after_ok:
            return i
        frm = i + len(kw)


def impl_type_name(header):
    s = header.strip()
    if s.startswith("<"):
        end = skip_angles(s, 0)
        s = s[min(end, len(s)):].lstrip()
    i = find_kw(s, "for")
    if i is not None:
        s = s[i + 3:].lstrip()
    w = s.find(" where")
    if w >= 0:
        s = s[:w]
    s = s.lstrip("&*").lstrip()
    if s.startswith("mut "):
        s = s[4:].lstrip()
    if s.startswith("dyn "):
        s = s[4:].lstrip()
    lt = s.find("<")
    base = s[:lt] if lt >= 0 else s
    base = base.rstrip()
    seg = base.rsplit("::", 1)[-1]
    return "".join(c for c in seg if c.isalnum() or c == "_")


def module_of(path):
    p = path
    if p.startswith("rust/src/"):
        p = p[len("rust/src/"):]
    if p.endswith(".rs"):
        p = p[:-3]
    if p.endswith("/mod"):
        p = p[:-4]
    return p.replace("/", "::")


def parse_fields(body):
    out = []
    for _, part in split_top_level(body, ","):
        p = part.strip()
        while p.startswith("#["):
            e = p[2:].find("]")
            if e < 0:
                break
            p = p[2 + e + 1:].lstrip()
        ci = find_type_colon(p)
        if ci is None:
            continue
        name = trailing_ident(p[:ci].rstrip())
        if name is None:
            continue
        ty = p[ci + 1:].strip()
        if ty:
            out.append((name, ty))
    return out


class Fn:
    __slots__ = ("name", "qual", "file", "line", "sig", "body", "is_test")

    def __init__(self, **kw):
        for k, v in kw.items():
            setattr(self, k, v)

    def params(self):
        sig = self.sig
        k = skip_ws(sig, 2)
        while k < len(sig) and is_id(sig[k]):
            k += 1
        k = skip_ws(sig, k)
        if k < len(sig) and sig[k] == "<":
            k = skip_angles(sig, k)
        k = skip_ws(sig, k)
        if k >= len(sig) or sig[k] != "(":
            return []
        close = match_delim(sig, k, "(", ")")
        if close is None:
            return []
        inner = sig[k + 1:close]
        out = []
        for _, part in split_top_level(inner, ","):
            p = part.strip()
            if not p:
                continue
            ci = find_type_colon(p)
            if ci is None:
                continue
            name = trailing_ident(p[:ci].rstrip())
            if name is None:
                continue
            out.append((name, p[ci + 1:].strip()))
        return out

    def returns_guard(self):
        return any(
            g in self.sig
            for g in ("MutexGuard", "RwLockReadGuard", "RwLockWriteGuard")
        )


class FileRec:
    __slots__ = ("path", "code", "lines", "in_test", "strs", "fns")

    def __init__(self, path, code, lines, in_test, strs):
        self.path = path
        self.code = code
        self.lines = lines
        self.in_test = in_test
        self.strs = strs
        self.fns = []

    def is_test_line(self, line):
        idx = line - 1
        return self.in_test[idx] if 0 <= idx < len(self.in_test) else False


class Model:
    def __init__(self):
        self.files = []
        self.fns = []
        self.structs = []  # (name, module, file, line, fields)
        self.statics = []  # (name, ty, file, line)
        self.enums = []  # (name, file, variants[(name,line)])

    def add_file(self, path, scanned):
        lines, in_test, strs = scanned
        code = "\n".join(l[0] for l in lines)
        self.files.append(FileRec(path, code, lines, in_test, strs))
        parse_file_items(self, len(self.files) - 1)

    @staticmethod
    def is_lock_type(ty):
        return "Mutex<" in ty or "RwLock<" in ty


def parse_file_items(model, file):
    f = model.files[file]
    code = f.code
    in_test = f.in_test
    module = module_of(f.path)
    n = len(code)
    stack = []  # ('fn', idx) | ('qual', name) | ('other',)
    pending = None  # (off, scope)
    line = 1
    i = 0
    while i < n:
        b = code[i]
        if b == "\n":
            line += 1
            i += 1
            continue
        if b == "{":
            if pending is not None and pending[0] == i:
                sc = pending[1]
                pending = None
            else:
                sc = ("other",)
            if sc[0] == "fn":
                model.fns[sc[1]].body = (i + 1, i + 1)
            stack.append(sc)
            i += 1
            continue
        if b == "}":
            if stack:
                sc = stack.pop()
                if sc[0] == "fn" and model.fns[sc[1]].body is not None:
                    model.fns[sc[1]].body = (model.fns[sc[1]].body[0], i)
            i += 1
            continue
        if not is_id(b) or (i > 0 and is_id(code[i - 1])):
            i += 1
            continue
        ws = i
        we = i
        while we < n and is_id(code[we]):
            we += 1
        if pending is not None:
            i = we
            continue
        word = code[ws:we]
        if word == "fn":
            j = skip_ws(code, we)
            if j < n and code[j] == "(":
                i = we
                continue
            ns = j
            while j < n and is_id(code[j]):
                j += 1
            if j == ns:
                i = we
                continue
            name = code[ns:j]
            k = j
            paren = 0
            bracket = 0
            opn = None
            semi = None
            while k < n:
                c = code[k]
                if c == "(":
                    paren += 1
                elif c == ")":
                    paren -= 1
                elif c == "[":
                    bracket += 1
                elif c == "]":
                    bracket -= 1
                elif c == "{" and paren == 0 and bracket == 0:
                    opn = k
                    break
                elif c == ";" and paren == 0 and bracket == 0:
                    semi = k
                    break
                k += 1
            sig_end = opn if opn is not None else (semi if semi is not None else n)
            qual = None
            for s in reversed(stack):
                if s[0] == "qual":
                    qual = s[1]
                    break
                if s[0] == "fn":
                    break
            idx = len(model.fns)
            model.fns.append(
                Fn(
                    name=name,
                    qual=qual,
                    file=file,
                    line=line,
                    sig=code[ws:sig_end].strip(),
                    body=None,
                    is_test=(in_test[line - 1] if line - 1 < len(in_test) else False),
                )
            )
            model.files[file].fns.append(idx)
            if opn is not None:
                pending = (opn, ("fn", idx))
                line += code.count("\n", ws, opn)
                i = opn
            else:
                end = semi + 1 if semi is not None else n
                line += code.count("\n", ws, end)
                i = end
        elif word in ("impl", "trait"):
            is_trait = word == "trait"
            k = we
            paren = 0
            bracket = 0
            opn = None
            while k < n:
                c = code[k]
                if c == "(":
                    paren += 1
                elif c == ")":
                    paren -= 1
                elif c == "[":
                    bracket += 1
                elif c == "]":
                    bracket -= 1
                elif c == "{" and paren == 0 and bracket == 0:
                    opn = k
                    break
                elif c == ";" and paren == 0 and bracket == 0:
                    break
                k += 1
            if opn is None:
                i = we
                continue
            header = code[we:opn]
            if is_trait:
                s = skip_ws(header, 0)
                e = s
                while e < len(header) and is_id(header[e]):
                    e += 1
                ty = header[s:e]
            else:
                ty = impl_type_name(header)
            pending = (opn, ("qual", ty))
            line += code.count("\n", ws, opn)
            i = opn
        elif word in ("struct", "enum"):
            is_enum = word == "enum"
            j = skip_ws(code, we)
            ns = j
            while j < n and is_id(code[j]):
                j += 1
            if j == ns:
                i = we
                continue
            name = code[ns:j]
            item_line = line
            k = skip_ws(code, j)
            if k < n and code[k] == "<":
                k = skip_angles(code, k)
                k = skip_ws(code, k)
            paren = 0
            bracket = 0
            body_open = None
            while k < n:
                c = code[k]
                if (
                    c == "("
                    and body_open is None
                    and paren == 0
                    and bracket == 0
                    and not is_enum
                ):
                    break
                if c == "(":
                    paren += 1
                elif c == ")":
                    paren -= 1
                elif c == "[":
                    bracket += 1
                elif c == "]":
                    bracket -= 1
                elif c == "{" and paren == 0 and bracket == 0:
                    body_open = k
                    break
                elif c == ";" and paren == 0 and bracket == 0:
                    break
                k += 1
            handled = False
            if body_open is not None:
                close = match_delim(code, body_open, "{", "}")
                if close is not None:
                    body = code[body_open + 1:close]
                    if is_enum:
                        variants = []
                        for off, part in split_top_level(body, ","):
                            x = skip_ws(part, 0)
                            while part[x:x + 2] == "#[":
                                e = part.find("]", x)
                                if e < 0:
                                    break
                                x = skip_ws(part, e + 1)
                            vs = x
                            while x < len(part) and is_id(part[x]):
                                x += 1
                            if x > vs:
                                voff = body_open + 1 + off + vs
                                variants.append((part[vs:x], line_at(code, voff)))
                        model.enums.append((name, file, variants))
                    else:
                        model.structs.append(
                            (name, module, file, item_line, parse_fields(body))
                        )
                    line += code.count("\n", ws, close + 1)
                    i = close + 1
                    handled = True
            if not handled:
                if not is_enum:
                    model.structs.append((name, module, file, item_line, []))
                i = j
        elif word == "static":
            j = skip_ws(code, we)
            if code[j:j + 3] == "mut" and not is_id(
                code[j + 3] if j + 3 < n else "x"
            ):
                j = skip_ws(code, j + 3)
            ns = j
            while j < n and is_id(code[j]):
                j += 1
            if j == ns:
                i = we
                continue
            name = code[ns:j]
            k = skip_ws(code, j)
            if k >= n or code[k] != ":":
                i = we
                continue
            ty_start = k + 1
            t = ty_start
            depth = 0
            while t < n:
                c = code[t]
                if c in "([<":
                    depth += 1
                elif c == ">" and code[t - 1] == "-":
                    pass
                elif c in ")]>":
                    depth -= 1
                elif c in "=;" and depth == 0:
                    break
                t += 1
            model.statics.append((name, code[ty_start:min(t, n)].strip(), file, line))
            line += code.count("\n", ws, min(t, n))
            i = min(t, n)
        else:
            i = we
    return


# ── callgraph.rs ─────────────────────────────────────────────────────

KEYWORDS = {
    "if", "while", "for", "match", "return", "loop", "in", "as", "let", "mut",
    "ref", "move", "fn", "else", "break", "continue", "unsafe", "impl", "dyn",
    "where", "use", "pub", "crate", "super", "self", "await", "async",
    "static", "const", "type", "struct", "enum", "trait", "mod",
}

ENTRY_NAMES = {"route", "handle_connection", "accept_loop", "worker_loop"}


def is_serve_request_path(path):
    return path.startswith("rust/src/serve/") and not path.endswith("loadgen.rs")


def is_index_surface(path):
    return is_serve_request_path(path) and (
        path.endswith("/http.rs") or path.endswith("/protocol.rs")
    )


def extract(model, idx):
    f = model.fns[idx]
    fr = model.files[f.file]
    code = fr.code
    n = len(code)
    rng = f.body if f.body is not None else (0, 0)
    inner = [
        model.fns[j].body
        for j in fr.fns
        if j != idx
        and model.fns[j].body is not None
        and model.fns[j].body[0] >= rng[0]
        and model.fns[j].body[1] <= rng[1]
    ]
    shields = []
    for off in word_occurrences(code, "catch_unwind"):
        if off < rng[0] or off >= rng[1]:
            continue
        j = skip_ws(code, off + len("catch_unwind"))
        if j < n and code[j] == "(":
            close = match_paren(code, j)
            shields.append((j, close if close is not None else rng[1]))

    def shielded(o):
        return any(s <= o < e for s, e in shields)

    calls = []
    sites = []
    i = rng[0]
    while i < rng[1]:
        hit = next((r for r in inner if r[0] <= i < r[1]), None)
        if hit is not None:
            i = hit[1]
            continue
        c = code[i]
        if c == "[":
            p = code[i - 1] if i > 0 else " "
            if (is_id(p) or p == ")" or p == "]") and not shielded(i):
                sites.append((i, "index"))
            i += 1
            continue
        if (not (c.isalpha() and c.isascii()) and c != "_") or (
            i > 0 and is_id(code[i - 1])
        ):
            i += 1
            continue
        s = i
        e = i
        while e < rng[1] and is_id(code[e]):
            e += 1
        i = e
        word = code[s:e]
        j0 = skip_ws(code, e)
        if word in ("panic", "unreachable", "todo", "unimplemented") and (
            j0 < n and code[j0] == "!"
        ):
            if not shielded(s):
                sites.append((s, "macro"))
            continue
        if j0 < n and code[j0] == "!":
            continue
        prev_dot = s > 0 and code[s - 1] == "."
        if prev_dot and word in ("unwrap", "expect") and j0 < n and code[j0] == "(":
            on_lock = code[:s - 1].rstrip().endswith("lock()")
            if not on_lock and not shielded(s):
                sites.append((s, word))
            continue
        if word in KEYWORDS:
            continue
        j = j0
        if code[j:j + 3] == "::<":
            j = skip_ws(code, skip_angles(code, j + 2))
        if j >= n or code[j] != "(" or shielded(s):
            continue
        if prev_dot:
            rs = s - 1
            while rs > 0 and is_id(code[rs - 1]):
                rs -= 1
            pure_self = code[rs:s - 1] == "self" and (rs == 0 or code[rs - 1] != ".")
            kind = ("selfmethod",) if pure_self else ("method",)
        elif s >= 2 and code[s - 1] == ":" and code[s - 2] == ":":
            qe = s - 2
            qs = qe
            while qs > 0 and is_id(code[qs - 1]):
                qs -= 1
            q = code[qs:qe]
            if not q:
                continue
            if q[0].isupper() or q == "Self":
                kind = ("qualified", q)
            else:
                kind = ("free",)
        else:
            k = s
            while k > rng[0] and code[k - 1].isspace():
                k -= 1
            is_def = (
                k >= 2
                and code[k - 2:k] == "fn"
                and (k < 3 or not is_id(code[k - 3]))
            )
            if is_def or word[0].isupper():
                continue
            kind = ("free",)
        calls.append((s, word, kind))
    return calls, sites


class Resolver:
    def __init__(self, model, in_scope):
        self.model = model
        self.free = {}
        self.exact = {}
        self.by_name = {}
        for i, f in enumerate(model.fns):
            if not in_scope[i]:
                continue
            if f.qual is None:
                self.free.setdefault(f.name, []).append(i)
            else:
                self.exact.setdefault((f.qual, f.name), []).append(i)
                self.by_name.setdefault(f.name, []).append(i)

    def resolve(self, call, caller):
        _, name, kind = call
        if kind[0] == "free":
            allc = list(self.free.get(name, []))
            same = [
                t
                for t in allc
                if self.model.fns[t].file == self.model.fns[caller].file
            ]
            return same if same else allc
        if kind[0] == "selfmethod":
            q = self.model.fns[caller].qual
            if q is not None and (q, name) in self.exact:
                return list(self.exact[(q, name)])
            return list(self.by_name.get(name, []))
        if kind[0] == "method":
            return list(self.by_name.get(name, []))
        t = kind[1]
        if t == "Self":
            q = self.model.fns[caller].qual
            if q is None:
                return []
            t = q
        return list(self.exact.get((t, name), []))


def scope_mask(model):
    return [
        (not f.is_test)
        and f.body is not None
        and model.files[f.file].path.startswith("rust/src/")
        for f in model.fns
    ]


def display_name(model, i):
    f = model.fns[i]
    return f"{f.qual}::{f.name}" if f.qual is not None else f.name


def chain_of(model, parent, i):
    idxs = [i]
    cur = i
    while parent[cur] is not None:
        idxs.append(parent[cur])
        cur = parent[cur]
        if len(idxs) > 32:
            break
    idxs.reverse()
    return " -> ".join(display_name(model, k) for k in idxs)


def panic_reach(model, out):
    n = len(model.fns)
    in_scope = scope_mask(model)
    infos = [extract(model, i) if in_scope[i] else None for i in range(n)]
    resolver = Resolver(model, in_scope)
    visited = [False] * n
    parent = [None] * n
    queue = []
    for i in range(n):
        if not in_scope[i]:
            continue
        f = model.fns[i]
        if is_serve_request_path(model.files[f.file].path) and (
            f.name in ENTRY_NAMES or f.name.startswith("handle_")
        ):
            visited[i] = True
            queue.append(i)
    qi = 0
    while qi < len(queue):
        i = queue[qi]
        qi += 1
        if infos[i] is None:
            continue
        for c in infos[i][0]:
            for t in resolver.resolve(c, i):
                if not visited[t]:
                    visited[t] = True
                    parent[t] = i
                    queue.append(t)
    seen = set()
    for i in range(n):
        if not visited[i] or infos[i] is None:
            continue
        f = model.fns[i]
        fr = model.files[f.file]
        serve = is_serve_request_path(fr.path)
        index_surface = is_index_surface(fr.path)
        for off, kind in infos[i][1]:
            keep = index_surface if kind == "index" else (not serve)
            if not keep:
                continue
            ln = line_at(fr.code, off)
            if (f.file, ln) in seen:
                continue
            seen.add((f.file, ln))
            what = {
                "macro": "panic!-family macro",
                "unwrap": "`.unwrap()`",
                "expect": "`.expect()`",
                "index": "unchecked index/slice expression",
            }[kind]
            chain = chain_of(model, parent, i)
            out.append(
                (
                    fr.path,
                    ln,
                    "PANIC-REACH",
                    "error",
                    f"{what} reachable from serve entry via {chain} — return a "
                    "typed error, shield with catch_unwind, or allow-mark the "
                    "line with the invariant that rules the panic out",
                )
            )


# ── locks.rs ─────────────────────────────────────────────────────────

LOCK_METHODS = ("lock", "read", "write")


def base_type(ty):
    s = ty.strip()
    while True:
        s = s.lstrip("&").lstrip()
        if s.startswith("'"):
            w = next((k for k, c in enumerate(s) if c.isspace()), None)
            if w is None:
                return ""
            s = s[w:].lstrip()
            continue
        if s.startswith("mut "):
            s = s[4:].lstrip()
        if s.startswith("dyn "):
            s = s[4:].lstrip()
        head_end = s.find("<")
        head_end = head_end if head_end >= 0 else len(s)
        last = s[:head_end].rsplit("::", 1)[-1].strip()
        if last in ("Arc", "Rc", "Box") and head_end < len(s):
            close = s.rfind(">")
            if close >= 0:
                s = s[head_end + 1:close].strip()
                continue
        return last


def chain_back(code, dot):
    parts = []
    while True:
        s = dot
        while s > 0 and is_id(code[s - 1]):
            s -= 1
        if s == dot:
            return None
        parts.append(code[s:dot])
        if s >= 1 and code[s - 1] == ".":
            dot = s - 1
            continue
        if s >= 2 and code[s - 1] == ":" and code[s - 2] == ":":
            return None
        parts.reverse()
        return (parts, s)


def is_all_caps(s):
    return (
        bool(s)
        and all(c.isupper() or c.isdigit() or c == "_" for c in s)
        and any(c.isupper() for c in s)
    )


def find_struct(model, fn_idx, name):
    file = model.fns[fn_idx].file
    for st in model.structs:
        if st[0] == name and st[2] == file:
            return st
    for st in model.structs:
        if st[0] == name:
            return st
    return None


def resolve_chain(model, fn_idx, chain, method, memo, visiting):
    f = model.fns[fn_idx]
    root = chain[0]
    if root == "self":
        if f.qual is None:
            return None
        cur = f.qual
    else:
        param = next(((nm, ty) for nm, ty in f.params() if nm == root), None)
        if param is not None:
            if Model.is_lock_type(param[1]):
                return None
            cur = base_type(param[1])
        elif is_all_caps(root):
            file = f.file
            st = next(
                (s for s in model.statics if s[0] == root and s[2] == file), None
            ) or next((s for s in model.statics if s[0] == root), None)
            if st is None:
                return None
            if Model.is_lock_type(st[1]):
                return f"static {st[0]}" if len(chain) == 1 else None
            cur = base_type(st[1])
        else:
            return None
    if len(chain) == 1:
        if method is None:
            return None
        return wrapper_internal(model, cur, method, memo, visiting)
    for k in range(1, len(chain)):
        seg = chain[k]
        sd = find_struct(model, fn_idx, cur)
        if sd is None:
            return None
        fd = next((fd for fd in sd[4] if fd[0] == seg), None)
        if fd is None:
            return None
        if k == len(chain) - 1:
            if Model.is_lock_type(fd[1]):
                return f"{sd[0]}.{fd[0]}"
            if method is None:
                return None
            return wrapper_internal(model, base_type(fd[1]), method, memo, visiting)
        cur = base_type(fd[1])
    return None


def wrapper_internal(model, tname, method, memo, visiting):
    key = (tname, method)
    if key in memo:
        return memo[key]
    if key in visiting:
        return None
    visiting.add(key)
    result = None
    idx = next(
        (
            i
            for i, g in enumerate(model.fns)
            if g.qual == tname
            and g.name == method
            and g.returns_guard()
            and not g.is_test
            and g.body is not None
        ),
        None,
    )
    if idx is not None:
        for _, chain, word in scan_method_sites(model, idx):
            if chain[0] == "self":
                rid = resolve_chain(model, idx, chain, word, memo, visiting)
                if rid is not None:
                    result = rid
                    break
    visiting.discard(key)
    memo[key] = result
    return result


def scan_method_sites(model, idx):
    f = model.fns[idx]
    fr = model.files[f.file]
    code = fr.code
    n = len(code)
    rng = f.body if f.body is not None else (0, 0)
    inner = [
        model.fns[j].body
        for j in fr.fns
        if j != idx
        and model.fns[j].body is not None
        and model.fns[j].body[0] >= rng[0]
        and model.fns[j].body[1] <= rng[1]
    ]
    out = []
    i = rng[0]
    while i < rng[1]:
        hit = next((r for r in inner if r[0] <= i < r[1]), None)
        if hit is not None:
            i = hit[1]
            continue
        c = code[i]
        if (not (c.isalpha() and c.isascii()) and c != "_") or (
            i > 0 and is_id(code[i - 1])
        ):
            i += 1
            continue
        s = i
        e = i
        while e < rng[1] and is_id(code[e]):
            e += 1
        i = e
        word = code[s:e]
        if word not in LOCK_METHODS:
            continue
        if s == 0 or code[s - 1] != ".":
            continue
        j = skip_ws(code, e)
        if j >= n or code[j] != "(":
            continue
        j2 = skip_ws(code, j + 1)
        if j2 >= n or code[j2] != ")":
            continue
        cb = chain_back(code, s - 1)
        if cb is not None:
            out.append((cb[1], cb[0], word))
    return out


def scan_guard_calls(model, idx, guard_free):
    f = model.fns[idx]
    fr = model.files[f.file]
    code = fr.code
    n = len(code)
    rng = f.body if f.body is not None else (0, 0)
    inner = [
        model.fns[j].body
        for j in fr.fns
        if j != idx
        and model.fns[j].body is not None
        and model.fns[j].body[0] >= rng[0]
        and model.fns[j].body[1] <= rng[1]
    ]
    out = []
    i = rng[0]
    while i < rng[1]:
        hit = next((r for r in inner if r[0] <= i < r[1]), None)
        if hit is not None:
            i = hit[1]
            continue
        c = code[i]
        if (not (c.isalpha() and c.isascii()) and c != "_") or (
            i > 0 and is_id(code[i - 1])
        ):
            i += 1
            continue
        s = i
        e = i
        while e < rng[1] and is_id(code[e]):
            e += 1
        i = e
        word = code[s:e]
        if word not in guard_free or (s > 0 and code[s - 1] == "."):
            continue
        j = skip_ws(code, e)
        if j >= n or code[j] != "(":
            continue
        close = match_paren(code, j)
        if close is None:
            continue
        args = code[j + 1:close - 1]
        parts = split_top_level(args, ",")
        first = parts[0][1].strip() if parts else ""
        expr = first.lstrip("&").lstrip()
        if expr.startswith("mut "):
            expr = expr[4:]
        if expr and all(is_id(ch) or ch == "." for ch in expr):
            chain = expr.split(".")
            if all(p for p in chain):
                out.append((s, chain))
    return out


def enclosing_block_end(code, off, body):
    stack = []
    for i in range(body[0], body[1]):
        c = code[i]
        if c == "{":
            stack.append(i)
        elif c == "}":
            if stack:
                o = stack.pop()
                if o < off < i:
                    return i
    return body[1]


def hold_range(code, expr_start, body):
    k = expr_start
    while k > body[0] and code[k - 1] not in ";{}":
        k -= 1
    bound = bool(word_occurrences(code[k:expr_start], "let"))
    if bound:
        return (expr_start, enclosing_block_end(code, expr_start, body))
    depth = 0
    i = expr_start
    while i < body[1]:
        c = code[i]
        if c in "([":
            depth += 1
        elif c in ")]":
            if depth == 0:
                return (expr_start, i)
            depth -= 1
        elif c == "{" and depth == 0:
            end = match_delim(code, i, "{", "}")
            return (expr_start, end if end is not None else body[1])
        elif c == "}" and depth == 0:
            return (expr_start, i)
        elif c == ";" and depth == 0:
            return (expr_start, i)
        i += 1
    return (expr_start, body[1])


def extract_acqs(model, idx, guard_free, memo):
    f = model.fns[idx]
    fr = model.files[f.file]
    body = f.body if f.body is not None else (0, 0)
    visiting = set()
    out = []
    for root, chain, word in scan_method_sites(model, idx):
        rid = resolve_chain(model, idx, chain, word, memo, visiting)
        if rid is not None:
            out.append(
                (root, rid, hold_range(fr.code, root, body), line_at(fr.code, root))
            )
    for off, chain in scan_guard_calls(model, idx, guard_free):
        rid = resolve_chain(model, idx, chain, None, memo, visiting)
        if rid is not None:
            out.append(
                (off, rid, hold_range(fr.code, off, body), line_at(fr.code, off))
            )
    out.sort(key=lambda a: a[0])
    return out


def eventual(i, model, acqs, calls, resolver, memo, visiting):
    if memo[i] is not None:
        return dict(memo[i])
    if visiting[i]:
        return {}
    visiting[i] = True
    mp = {}
    path = model.files[model.fns[i].file].path
    for a in acqs[i]:
        if a[1] not in mp:
            mp[a[1]] = (path, a[3])
    for c in calls[i]:
        if c[1] in LOCK_METHODS:
            continue
        for t in resolver.resolve(c, i):
            for rid, site in eventual(
                t, model, acqs, calls, resolver, memo, visiting
            ).items():
                if rid not in mp:
                    mp[rid] = site
    visiting[i] = False
    memo[i] = dict(mp)
    return mp


def lock_order(model, out):
    n = len(model.fns)
    in_scope = scope_mask(model)
    resolver = Resolver(model, in_scope)
    guard_free = {
        f.name
        for i, f in enumerate(model.fns)
        if in_scope[i] and f.qual is None and f.returns_guard()
    }
    wrap_memo = {}
    acqs = [
        extract_acqs(model, i, guard_free, wrap_memo) if in_scope[i] else []
        for i in range(n)
    ]
    calls = [extract(model, i)[0] if in_scope[i] else [] for i in range(n)]
    ev_memo = [None] * n
    visiting = [False] * n
    edges = {}
    for i in range(n):
        if not acqs[i]:
            continue
        path = model.files[model.fns[i].file].path
        for a in acqs[i]:
            for b2 in acqs[i]:
                if b2[0] > a[0] and b2[0] < a[2][1]:
                    edges.setdefault((a[1], b2[1]), (path, a[3], path, b2[3]))
            for c in calls[i]:
                if c[0] <= a[0] or c[0] >= a[2][1] or c[1] in LOCK_METHODS:
                    continue
                for t in resolver.resolve(c, i):
                    ev = eventual(
                        t, model, acqs, calls, resolver, ev_memo, visiting
                    )
                    for id2, (p2, l2) in sorted(ev.items()):
                        edges.setdefault((a[1], id2), (path, a[3], p2, l2))
    nodes = sorted({x for k in edges for x in k})
    node_ix = {s: i for i, s in enumerate(nodes)}
    adj = {}
    for a, b in edges:
        adj.setdefault(node_ix[a], set()).add(node_ix[b])

    index = [None] * len(nodes)
    low = [0] * len(nodes)
    on_stack = [False] * len(nodes)
    stack = []
    sccs = []
    counter = [0]

    def strongconnect(v):
        index[v] = counter[0]
        low[v] = counter[0]
        counter[0] += 1
        stack.append(v)
        on_stack[v] = True
        for w in sorted(adj.get(v, ())):
            if index[w] is None:
                strongconnect(w)
                low[v] = min(low[v], low[w])
            elif on_stack[w]:
                low[v] = min(low[v], index[w])
        if low[v] == index[v]:
            scc = []
            while stack:
                w = stack.pop()
                on_stack[w] = False
                scc.append(w)
                if w == v:
                    break
            scc.sort()
            sccs.append(scc)

    for v in range(len(nodes)):
        if index[v] is None:
            strongconnect(v)
    sccs.sort()
    for scc in sccs:
        cyclic = len(scc) > 1 or (nodes[scc[0]], nodes[scc[0]]) in edges
        if not cyclic:
            continue
        member = {nodes[v] for v in scc}
        intra = [
            (k, v)
            for k, v in sorted(edges.items())
            if k[0] in member and k[1] in member
        ]
        if not intra:
            continue
        _, (_, _, ap, al) = intra[0]
        parts = [
            f"{a} ({p1}:{l1}) then {b} ({p2}:{l2})"
            for (a, b), (p1, l1, p2, l2) in intra
        ]
        out.append(
            (
                ap,
                al,
                "LOCK-ORDER",
                "error",
                "lock-order cycle: "
                + "; ".join(parts)
                + " — acquire these locks in one global order (or collapse "
                "them into one) so no interleaving can deadlock",
            )
        )


# ── contract.rs ──────────────────────────────────────────────────────

LEDGER_PATH = "tools/audit/unsafe.ledger"


def looks_like_route(text):
    t = text.rstrip("/")
    return (
        len(t) >= 2
        and t[0] == "/"
        and "a" <= t[1] <= "z"
        and all(("a" <= c <= "z") or c.isdigit() or c in "_/" for c in t[1:])
    )


def metric_name(text):
    end = next(
        (
            k
            for k, c in enumerate(text)
            if not (("a" <= c <= "z") or c.isdigit() or c == "_")
        ),
        len(text),
    )
    return text[:end]


def err_map(model, api_md, out):
    kinds = next(
        (
            e
            for e in model.enums
            if e[0] == "ErrorKind" and model.files[e[1]].path == "rust/src/error.rs"
        ),
        None,
    )
    http = next((f for f in model.files if f.path == "rust/src/serve/http.rs"), None)
    if kinds is not None and http is not None:
        epath = model.files[kinds[1]].path
        for variant, ln in kinds[2]:
            needle = f"ErrorKind::{variant}"
            mapped = any(
                not http.is_test_line(line_at(http.code, off))
                for off in word_occurrences(http.code, needle)
            )
            if not mapped:
                out.append(
                    (
                        epath,
                        ln,
                        "ERR-MAP",
                        "error",
                        f"ErrorKind::{variant} has no HTTP status mapping in "
                        "rust/src/serve/http.rs — every error kind a fit can "
                        "return must map to a status (see error_status)",
                    )
                )
    if api_md is None:
        return
    seen_routes = set()
    for f in model.files:
        if f.path not in ("rust/src/serve/http.rs", "rust/src/serve/protocol.rs"):
            continue
        for ln, text in f.strs:
            if f.is_test_line(ln) or not looks_like_route(text):
                continue
            route = text.rstrip("/")
            if route in seen_routes:
                continue
            seen_routes.add(route)
            if route not in api_md:
                out.append(
                    (
                        f.path,
                        ln,
                        "ERR-MAP",
                        "error",
                        f'route "{route}" is served but not documented in '
                        "docs/API.md — document it (or rename the literal if "
                        "it is not a route)",
                    )
                )
    seen_metrics = set()
    for f in model.files:
        if not f.path.startswith("rust/src/"):
            continue
        for ln, text in f.strs:
            if f.is_test_line(ln) or not text.startswith("calars_"):
                continue
            name = metric_name(text)
            if len(name) <= len("calars_") or name in seen_metrics:
                continue
            seen_metrics.add(name)
            if name not in api_md:
                out.append(
                    (
                        f.path,
                        ln,
                        "ERR-MAP",
                        "error",
                        f'metric "{name}" is registered but not documented in '
                        "docs/API.md — the /metrics surface is part of the API "
                        "contract",
                    )
                )


def in_unsafe_scope(path):
    return path.startswith("rust/src/par/") or path.startswith("rust/src/kern/simd/")


def unsafe_sites(model):
    out = {}
    for f in model.files:
        if not in_unsafe_scope(f.path):
            continue
        lines = [
            line_at(f.code, off)
            for off in word_occurrences(f.code, "unsafe")
            if not f.is_test_line(line_at(f.code, off))
        ]
        if lines:
            out[f.path] = lines
    return dict(sorted(out.items()))


def ledger_text(model):
    out = (
        "# unsafe budget — one `path count` per file in the sanctioned unsafe\n"
        "# regions (rust/src/par/, rust/src/kern/simd/).  Regenerate with\n"
        "# `calars audit --update-unsafe-ledger` after reviewing every new "
        "block.\n"
    )
    for path, sites in sorted(unsafe_sites(model).items()):
        out += f"{path} {len(sites)}\n"
    return out


def unsafe_budget(model, ledger, out):
    sites = unsafe_sites(model)
    if ledger is None:
        for path, lines in sorted(sites.items()):
            out.append(
                (
                    path,
                    lines[0],
                    "UNSAFE-BUDGET",
                    "error",
                    f"{len(lines)} unsafe block(s) but no ledger at "
                    f"{LEDGER_PATH} — review them and check the ledger in "
                    "with --update-unsafe-ledger",
                )
            )
        return
    entries = {}
    for idx, raw in enumerate(ledger.splitlines()):
        line = idx + 1
        l = raw.strip()
        if not l or l.startswith("#"):
            continue
        parts = l.split()
        if len(parts) != 2:
            out.append(
                (
                    LEDGER_PATH,
                    line,
                    "UNSAFE-BUDGET",
                    "error",
                    f"malformed ledger line `{l}` — expected `path count`",
                )
            )
            continue
        path, count = parts
        if not count.isdigit():
            out.append(
                (
                    LEDGER_PATH,
                    line,
                    "UNSAFE-BUDGET",
                    "error",
                    f"malformed ledger count in `{l}` — expected `path count`",
                )
            )
            continue
        entries[path] = (int(count), line)
    for path, lines in sorted(sites.items()):
        if path not in entries:
            out.append(
                (
                    path,
                    lines[0],
                    "UNSAFE-BUDGET",
                    "error",
                    f"{len(lines)} unsafe block(s) but no entry in "
                    f"{LEDGER_PATH} — review them and regenerate with "
                    "--update-unsafe-ledger",
                )
            )
        else:
            count, lline = entries[path]
            if len(lines) > count:
                out.append(
                    (
                        path,
                        lines[count],
                        "UNSAFE-BUDGET",
                        "error",
                        f"unsafe count grew from {count} (ledgered) to "
                        f"{len(lines)} — justify the new block(s) and "
                        "regenerate with --update-unsafe-ledger",
                    )
                )
            elif len(lines) < count:
                out.append(
                    (
                        LEDGER_PATH,
                        lline,
                        "UNSAFE-BUDGET",
                        "warning",
                        f"{path} ledgered at {count} but now has "
                        f"{len(lines)} unsafe block(s) — regenerate to "
                        "tighten the budget",
                    )
                )
    for path in sorted(entries):
        count, lline = entries[path]
        if path not in sites:
            out.append(
                (
                    LEDGER_PATH,
                    lline,
                    "UNSAFE-BUDGET",
                    "warning",
                    f"stale ledger entry for {path} — the file has no unsafe "
                    "blocks (or no longer exists); regenerate to drop it",
                )
            )


# ── markers (rules.rs) ───────────────────────────────────────────────

NEW_RULES = {"PANIC-REACH", "LOCK-ORDER", "ERR-MAP", "UNSAFE-BUDGET"}


def collect_markers(path, lines):
    out = []
    for idx, (_, comment) in enumerate(lines):
        frm = 0
        while True:
            rel = comment.find("audit: allow(", frm)
            if rel < 0:
                break
            i = rel + len("audit: allow(")
            rest = comment[i:]
            close = rest.find(")")
            if close < 0:
                break
            inner = rest[:close]
            if "," in inner:
                r, scope = inner.split(",", 1)
                rule, file_scope = r.strip(), scope.strip() == "file"
            else:
                rule, file_scope = inner.strip(), False
            after = rest[close + 1:].lstrip()
            has_reason = after.startswith("--") and bool(after[2:].strip())
            out.append(
                {
                    "path": path,
                    "line": idx + 1,
                    "rule": rule,
                    "file_scope": file_scope,
                    "has_reason": has_reason,
                    "used": False,
                }
            )
            frm = i + close
    return out


def apply_markers(findings, markers):
    kept = []
    suppressed = 0
    for f in findings:
        hit = False
        for m in markers:
            if m["path"] != f[0] or m["rule"] != f[2] or not m["has_reason"]:
                continue
            if m["file_scope"] or m["line"] == f[1] or m["line"] + 1 == f[1]:
                m["used"] = True
                hit = True
        if hit:
            suppressed += 1
        else:
            kept.append(f)
    return kept, suppressed


# ── run_audit mirror (new rules only) ────────────────────────────────


def collect_rs(d):
    out = []
    try:
        entries = sorted(os.listdir(d))
    except OSError:
        return out
    for name in entries:
        p = os.path.join(d, name)
        if os.path.isdir(p):
            out.extend(collect_rs(p))
        elif name.endswith(".rs"):
            out.append(p)
    return out


def run(root, update_ledger=False):
    model = Model()
    markers = []
    for wd in ("rust/src", "rust/tests", "benches"):
        absd = os.path.join(root, wd)
        if not os.path.isdir(absd):
            continue
        for fp in collect_rs(absd):
            with open(fp, "rb") as fh:
                src = fh.read()
            rel = os.path.relpath(fp, root).replace(os.sep, "/")
            scanned = scan(src)
            markers.extend(collect_markers(rel, scanned[0]))
            model.add_file(rel, scanned)
    findings = []
    panic_reach(model, findings)
    lock_order(model, findings)
    api_path = os.path.join(root, "docs/API.md")
    api_md = None
    if os.path.isfile(api_path):
        with open(api_path, encoding="utf-8", errors="replace") as fh:
            api_md = fh.read()
    err_map(model, api_md, findings)
    if update_ledger:
        ledger = ledger_text(model)
        with open(os.path.join(root, LEDGER_PATH), "w", encoding="utf-8") as fh:
            fh.write(ledger)
    else:
        lp = os.path.join(root, LEDGER_PATH)
        ledger = None
        if os.path.isfile(lp):
            with open(lp, encoding="utf-8", errors="replace") as fh:
                ledger = fh.read()
    unsafe_budget(model, ledger, findings)
    new_markers = [m for m in markers if m["rule"] in NEW_RULES]
    kept, suppressed = apply_markers(findings, new_markers)
    kept.sort(key=lambda f: (f[0], f[1], f[2]))
    return kept, suppressed, new_markers


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else "."
    update = "--update-ledger" in sys.argv
    kept, suppressed, new_markers = run(root, update)
    for path, line, rule, sev, msg in kept:
        print(f"{path}:{line}: {sev}[{rule}]: {msg}")
    unused = [m for m in new_markers if not m["used"]]
    for m in unused:
        print(
            f"{m['path']}:{m['line']}: warning[ALLOW-UNUSED]: marker for "
            f"{m['rule']} suppresses nothing"
        )
    print(
        f"-- {len(kept)} finding(s), {suppressed} suppressed, "
        f"{len(new_markers)} new-rule marker(s), {len(unused)} unused"
    )


if __name__ == "__main__":
    main()
